//! Property tests for the shared-fabric arbitration ledger
//! (DESIGN.md §Fabric-Contention): seeded random booking sequences pin
//! the invariants the cost model rests on —
//!
//! * **conservation** — every byte a booking takes lands in exactly one
//!   window and one module bucket, so the per-window, per-port and
//!   per-module ledgers all sum to the booked total;
//! * **monotonicity** — a transfer's completion time never *improves*
//!   when more load is offered first (residual budgets only shrink);
//! * **Off identity** — Off mode reproduces the unloaded
//!   [`FabricLatencies`]-era arithmetic bit-for-bit and records nothing;
//! * **balance** — uniform striping (§3.3.1) keeps the per-module byte
//!   ledger exactly balanced; whole-transfer hashing may only skew it.

use fenghuang::config::fh4_15xm;
use fenghuang::fabric::contention::{ContentionConfig, ContentionMode, FabricClock};
use fenghuang::models::mfu;
use fenghuang::traffic::XorShift;
use fenghuang::units::{Bandwidth, Bytes, Seconds};

fn sys() -> fenghuang::config::SystemConfig {
    fh4_15xm(Bandwidth::tbps(4.8))
}

fn clock(mode: ContentionMode, ports: usize, interleave: bool) -> FabricClock {
    let cfg = ContentionConfig { mode, module_interleave: interleave, ..Default::default() }
        .resolved(ports);
    FabricClock::for_system(&sys(), cfg).expect("clock")
}

/// A seeded random booking plan: (start, bytes, port, key).
fn plan(seed: u64, n: usize, ports: usize) -> Vec<(Seconds, Bytes, usize, u64)> {
    let mut rng = XorShift::new(seed);
    (0..n)
        .map(|_| {
            // Starts inside a 50 ms horizon, sizes from 4 KiB to ~2 GiB
            // (log-uniform, so both latency- and bandwidth-dominated
            // messages appear).
            let start = Seconds::new(rng.next_f64() * 0.05);
            let log_span = (Bytes::gib(2.0).value() / Bytes::kib(4.0).value()).ln();
            let bytes = Bytes(Bytes::kib(4.0).value() * (rng.next_f64() * log_span).exp());
            let port = (rng.next_u64() % ports as u64) as usize;
            let key = rng.next_u64();
            (start, bytes, port, key)
        })
        .collect()
}

#[test]
fn booked_bytes_are_conserved_across_windows_ports_and_modules() {
    for (mode, interleave) in [
        (ContentionMode::Shared, true),
        (ContentionMode::PerModule, true),
        (ContentionMode::PerModule, false),
    ] {
        for seed in [3u64, 17, 90210] {
            let mut c = clock(mode, 8, interleave);
            let mut offered = 0.0f64;
            for (start, bytes, port, key) in plan(seed, 120, 8) {
                c.book(start, bytes, port, key);
                offered += bytes.value();
            }
            let booked = c.booked_bytes().value();
            let tol = 1e-6 * offered.max(1.0);
            assert!(
                (booked - offered).abs() <= tol,
                "{mode:?}/{interleave}/{seed}: offered {offered} vs booked {booked}"
            );
            let windowed: f64 = c.window_bytes().iter().map(|(_, b)| b.value()).sum();
            assert!(
                (windowed - booked).abs() <= tol,
                "{mode:?}/{interleave}/{seed}: window ledger {windowed} vs booked {booked}"
            );
            let by_port: f64 = c.port_bytes().iter().map(|b| b.value()).sum();
            assert!((by_port - booked).abs() <= tol, "port ledger {by_port} vs {booked}");
            let by_module: f64 = c.module_bytes().iter().map(|b| b.value()).sum();
            assert!(
                (by_module - booked).abs() <= tol,
                "module ledger {by_module} vs {booked}"
            );
            let r = c.report();
            assert_eq!(r.transfers, 120);
            assert!((r.bytes.value() - booked).abs() <= tol);
        }
    }
}

#[test]
fn completion_times_are_monotone_in_offered_load() {
    // The same probe transfer, booked after ever more background load:
    // residual budgets only shrink, so its completion never improves.
    let probe_bytes = Bytes::mib(512.0);
    for (mode, interleave) in [
        (ContentionMode::Shared, true),
        (ContentionMode::PerModule, true),
        (ContentionMode::PerModule, false),
    ] {
        let mut prev = None;
        for background in [0usize, 4, 16, 48, 96] {
            let mut c = clock(mode, 8, interleave);
            let load = plan(11, background, 8);
            for (start, bytes, port, key) in load {
                // Background concentrated at t=0..50ms, like the probe.
                c.book(start, bytes, port, key);
            }
            let b = c.book(Seconds::ms(10.0), probe_bytes, 3, 42);
            assert!(b.queueing.value() >= 0.0);
            assert!(
                b.completion >= Seconds::ms(10.0) + b.serialization - Seconds::ns(1.0),
                "completion can never beat start + serialization"
            );
            if let Some(prev) = prev {
                assert!(
                    b.completion >= prev,
                    "{mode:?}/{interleave}: probe completed earlier under \
                     {background} background transfers ({:?} < {prev:?})",
                    b.completion
                );
            }
            prev = Some(b.completion);
        }
    }
}

#[test]
fn same_port_load_queues_harder_than_spread_load() {
    // All background on the probe's port vs spread over 8 ports: the
    // port-budget constraint must bite at least as hard.
    let mk = |same_port: bool| {
        let mut c = clock(ContentionMode::Shared, 8, true);
        for i in 0..12u64 {
            let port = if same_port { 3 } else { (i % 8) as usize };
            c.book(Seconds::ZERO, Bytes::mib(256.0), port, i);
        }
        c.book(Seconds::ZERO, Bytes::mib(256.0), 3, 99).completion
    };
    assert!(mk(true) >= mk(false));
}

#[test]
fn off_mode_is_bit_identical_to_the_unloaded_charges() {
    let mut c = clock(ContentionMode::Off, 8, true);
    let bw = sys().fabric_bw;
    let mut rng = XorShift::new(5);
    for _ in 0..64 {
        let bytes = Bytes(4096.0 + rng.next_f64() * 2e9);
        let start = Seconds::new(rng.next_f64());
        let b = c.book(start, bytes, (rng.next_u64() % 8) as usize, rng.next_u64());
        // Exactly the Eq 4.1 unloaded serialization every consumer used
        // before this subsystem existed — same f64 ops, same bits.
        assert_eq!(b.serialization, mfu::transfer_time(bytes, bw));
        assert_eq!(b.completion, start + mfu::transfer_time(bytes, bw));
        assert_eq!(b.queueing, Seconds::ZERO);
    }
    // Nothing was recorded: the Off clock is inert, so any consumer
    // holding one behaves as if it held none.
    assert_eq!(c.transfers(), 0);
    assert_eq!(c.booked_bytes(), Bytes::ZERO);
    let r = c.report();
    assert_eq!(r.transfers, 0);
    assert_eq!(r.busy_frac, 0.0);
    assert_eq!(r.queue_p99, Seconds::ZERO);
}

#[test]
fn interleave_balances_modules_exactly_hashing_only_skews() {
    for seed in [1u64, 8, 23] {
        let mut striped = clock(ContentionMode::PerModule, 8, true);
        let mut hashed = clock(ContentionMode::PerModule, 8, false);
        for (start, bytes, port, key) in plan(seed, 90, 8) {
            striped.book(start, bytes, port, key);
            hashed.book(start, bytes, port, key);
        }
        let rs = striped.report();
        assert!(
            (rs.module_imbalance - 1.0).abs() < 1e-9,
            "seed {seed}: uniform striping must balance exactly, got {}",
            rs.module_imbalance
        );
        let max = rs.module_bytes.iter().map(|b| b.value()).fold(0.0, f64::max);
        let min = rs.module_bytes.iter().map(|b| b.value()).fold(f64::INFINITY, f64::min);
        assert!((max - min).abs() <= 1e-6 * max.max(1.0), "striped spread {min}..{max}");
        let rh = hashed.report();
        assert!(
            rh.module_imbalance >= rs.module_imbalance - 1e-9,
            "seed {seed}: hashed {} below striped {}",
            rh.module_imbalance,
            rs.module_imbalance
        );
        assert!(rh.hotspot_module < 8);
    }
}

#[test]
fn booking_sequences_are_deterministic() {
    let run = |seed| {
        let mut c = clock(ContentionMode::PerModule, 8, false);
        let mut fingerprint = Vec::new();
        for (start, bytes, port, key) in plan(seed, 60, 8) {
            let b = c.book(start, bytes, port, key);
            fingerprint.push((b.completion.value(), b.queueing.value()));
        }
        let r = c.report();
        (fingerprint, r.queue_p99.value(), r.module_imbalance, r.hotspot_module)
    };
    assert_eq!(run(77), run(77), "same plan must reproduce the ledger bit-for-bit");
    assert_ne!(run(77).0, run(78).0, "different plans must differ");
}
