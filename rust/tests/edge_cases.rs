//! Edge cases and failure injection across the stack.

use fenghuang::config::{baseline8, fh4_15xm, SystemConfig};
use fenghuang::coordinator::router::{Policy, Router};
use fenghuang::coordinator::{synthetic_workload, Batcher, Scheduler, SimBackend};
use fenghuang::fabric::analysis::{speedup, SpeedupConfig};
use fenghuang::fabric::tab::TabPool;
use fenghuang::models::arch;
use fenghuang::sim;
use fenghuang::trace::{generate, Phase, TraceConfig};
use fenghuang::units::{Bandwidth, Bytes, Seconds};
use fenghuang::FhError;

// ---------------------------------------------------------------------------
// Capacity / thrash failure paths.
// ---------------------------------------------------------------------------

#[test]
fn baseline_with_tiny_hbm_reports_thrash() {
    let mut sys = baseline8();
    sys.local_capacity = Some(Bytes::gb(1.0)); // GPT-3 shard cannot fit
    let err = sim::simulate(&sys, &arch::gpt3_175b(), 8, Phase::Decode { kv_len: 1024 })
        .unwrap_err();
    match err {
        FhError::LocalMemoryThrash { need_gb, cap_gb, .. } => {
            assert!(need_gb > cap_gb);
        }
        other => panic!("expected thrash, got {other}"),
    }
}

#[test]
fn fh_unlimited_local_never_thrashes() {
    let sys = fh4_15xm(Bandwidth::tbps(4.0));
    assert!(sys.local_capacity.is_none());
    for kv in [128u64, 131072] {
        sim::simulate(&sys, &arch::qwen3_235b(), 8, Phase::Decode { kv_len: kv }).unwrap();
    }
}

#[test]
fn pool_exhaustion_then_recovery() {
    let pool = TabPool::new(1024, 2, 64);
    let a = pool.alloc(1000).unwrap();
    assert!(matches!(pool.alloc(100), Err(FhError::PoolExhausted { .. })));
    pool.free(a);
    pool.alloc(1024).unwrap();
}

// ---------------------------------------------------------------------------
// Degenerate workloads.
// ---------------------------------------------------------------------------

#[test]
fn single_gpu_single_batch_trace_runs() {
    // TP=1 means no collectives at all.
    let tr = generate(&TraceConfig {
        model: arch::gpt2(),
        tp: 1,
        batch: 1,
        phase: Phase::Decode { kv_len: 1 },
    });
    assert!(tr.num_collectives() > 0); // allreduce nodes still exist…
    let mut sys = baseline8();
    sys.num_gpus = 1;
    let r = sim::simulate(&sys, &arch::gpt2(), 1, Phase::Decode { kv_len: 1 }).unwrap();
    assert!(r.total.value() > 0.0);
}

#[test]
fn scheduler_with_no_requests_finishes_immediately() {
    let backend = SimBackend::new(fh4_15xm(Bandwidth::tbps(4.8)), arch::gpt3_175b(), 8);
    let mut sched = Scheduler::new(backend, Batcher::new(8, 64, 4096));
    sched.submit_all(vec![]);
    sched.run_to_completion().unwrap();
    assert_eq!(sched.metrics.completed, 0);
    assert_eq!(sched.clock(), Seconds::ZERO);
}

#[test]
fn scheduler_all_rejected_still_terminates() {
    let backend = SimBackend::new(fh4_15xm(Bandwidth::tbps(4.8)), arch::gpt3_175b(), 8);
    let mut sched = Scheduler::new(backend, Batcher::new(8, 64, 8)); // max prompt 8
    let reqs = synthetic_workload(5, 1024, 4, Seconds::ms(1.0)); // prompts ≫ 8
    sched.submit_all(reqs);
    sched.run_to_completion().unwrap();
    assert_eq!(sched.metrics.completed, 0);
    assert_eq!(sched.metrics.rejected, 5);
}

// ---------------------------------------------------------------------------
// Config robustness.
// ---------------------------------------------------------------------------

#[test]
fn config_roundtrip_fh_with_unlimited_capacity() {
    let sys = fh4_15xm(Bandwidth::tbps(5.6));
    let text = sys.to_toml().unwrap();
    let back = SystemConfig::from_toml(&text).unwrap();
    assert_eq!(back.name, "FH4-1.5xM");
    assert!(back.local_capacity.is_none());
    assert!((back.fabric_bw.as_tbps() - 5.6).abs() < 1e-9);
    assert!((back.latencies.tab_read.as_ns() - 220.0).abs() < 1e-9);
}

#[test]
fn config_parser_rejects_garbage() {
    assert!(SystemConfig::from_toml("not a config").is_err());
    assert!(SystemConfig::from_toml("name = \"x\"\n").is_err()); // missing keys
    let sys = baseline8();
    let mut text = sys.to_toml().unwrap();
    text = text.replace("fabric = \"nvlink\"", "fabric = \"carrier-pigeon\"");
    assert!(SystemConfig::from_toml(&text).is_err());
}

#[test]
fn config_file_roundtrip() {
    let dir = std::env::temp_dir().join(format!("fh_cfg_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("node.toml");
    baseline8().save(&path).unwrap();
    let back = SystemConfig::load(&path).unwrap();
    assert_eq!(back.num_gpus, 8);
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------------
// Multi-replica routing + serving.
// ---------------------------------------------------------------------------

#[test]
fn routed_multi_replica_serving_balances_and_completes() {
    // Route a workload across 3 FH replicas, run each replica's schedule,
    // and check global completion + rough balance.
    let replicas = 3;
    let mut router = Router::new(replicas, Policy::LeastLoaded);
    let reqs = synthetic_workload(30, 1024, 16, Seconds::ms(1.0));
    let mut per_replica: Vec<Vec<_>> = vec![Vec::new(); replicas];
    for r in reqs {
        let idx = router.route(&r);
        per_replica[idx].push(r);
    }
    let sizes: Vec<usize> = per_replica.iter().map(|v| v.len()).collect();
    assert!(sizes.iter().all(|&s| s >= 6), "unbalanced routing: {sizes:?}");
    let mut total = 0;
    for bucket in per_replica {
        let backend = SimBackend::new(fh4_15xm(Bandwidth::tbps(4.8)), arch::qwen3_235b(), 8);
        let mut sched = Scheduler::new(backend, Batcher::new(8, 64, 1 << 20));
        sched.submit_all(bucket);
        sched.run_to_completion().unwrap();
        total += sched.metrics.completed;
    }
    assert_eq!(total, 30);
}

// ---------------------------------------------------------------------------
// §3.1 scaling claims: N and bandwidth sensitivity of the analysis.
// ---------------------------------------------------------------------------

#[test]
fn speedup_grows_with_world_size() {
    // Enabler 1 is 2(N−1): more GPUs → bigger ring penalty → bigger win.
    let mut last = 0.0;
    for n in [2usize, 4, 8, 16, 32] {
        let cfg = SpeedupConfig { world: n, ..Default::default() };
        let r = speedup(&cfg);
        assert!(r.overall_latency_bound > last);
        last = r.overall_latency_bound;
    }
    // N=8 stays the paper's 70×.
    let r = speedup(&SpeedupConfig::default());
    assert_eq!(r.overall_latency_bound, 70.0);
}

#[test]
fn trace_scales_linearly_with_layers() {
    let mut small = arch::gpt2();
    small.layers = 6;
    let t6 = generate(&TraceConfig {
        model: small.clone(),
        tp: 2,
        batch: 2,
        phase: Phase::Decode { kv_len: 64 },
    });
    small.layers = 12;
    let t12 = generate(&TraceConfig {
        model: small,
        tp: 2,
        batch: 2,
        phase: Phase::Decode { kv_len: 64 },
    });
    assert_eq!(t12.ops.len() - 2, 2 * (t6.ops.len() - 2));
}

#[test]
fn op_names_render_stably() {
    let tr = generate(&TraceConfig {
        model: arch::qwen3_235b(),
        tp: 4,
        batch: 8,
        phase: Phase::Decode { kv_len: 64 },
    });
    assert_eq!(tr.ops[0].name(), "embed");
    assert_eq!(tr.ops[1].name(), "l0.qkv");
    assert!(tr.ops.iter().any(|o| o.name() == "l93.ar_ffn"));
    assert_eq!(tr.ops.last().unwrap().name(), "lm_head");
}
