//! Telemetry properties (DESIGN.md §Telemetry), on BOTH cluster cores:
//!
//! * **Stall-attribution conservation** — every recorded span
//!   reconstructs its measured TTFT *bitwise* from its components
//!   (`RequestSpan::conserves_ttft`), and the fleet ledger is exactly
//!   the per-replica charge/merge fold of the published spans — no
//!   latency second appears or disappears in attribution.
//! * **Off is a strict passthrough** — a telemetry-off run publishes no
//!   telemetry and stays deterministic; a telemetry-ON run leaves every
//!   count (completions, tokens, SLO verdicts, shed/rejected)
//!   untouched.
//! * **Sampler/exporter sanity** — samples are tick-ordered with
//!   monotone cumulative counters, attainment stays in [0, 1], and the
//!   exporters render every span and sample.

use fenghuang::config::FlashConfig;
use fenghuang::coordinator::tenancy::TenantsConfig;
use fenghuang::coordinator::{
    AutoscaleConfig, Cluster, ClusterConfig, ClusterReport, PrefixCacheConfig, Request,
};
use fenghuang::faults::FaultSchedule;
use fenghuang::models::arch::gpt3_175b;
use fenghuang::telemetry::export::{chrome_trace, timeseries_csv};
use fenghuang::telemetry::{SpanKind, StallLedger, TelemetryConfig};
use fenghuang::traffic::{
    self, generate_tenant_workload, ArrivalConfig, ArrivalPattern, TrafficConfig, WorkloadMix,
};
use fenghuang::units::{Bytes, Seconds};

fn chat_reqs(requests: usize, seed: u64) -> Vec<Request> {
    let tc = TrafficConfig {
        arrivals: ArrivalConfig {
            pattern: ArrivalPattern::Bursty,
            qps: 12.0,
            ..Default::default()
        },
        mix: WorkloadMix::parse("chat+rag").unwrap(),
        requests,
        seed,
        max_prompt: 4096,
        ..Default::default()
    };
    traffic::generate(&tc).expect("workload")
}

fn telemetry(ms: f64) -> Option<TelemetryConfig> {
    Some(TelemetryConfig { interval: Seconds::ms(ms) })
}

/// The seeded scenario matrix: every cluster feature family with
/// telemetry armed.
fn scenarios() -> Vec<(&'static str, ClusterConfig, usize, Vec<Request>)> {
    let agentic = TrafficConfig {
        mix: WorkloadMix::parse("agentic").unwrap(),
        requests: 28,
        seed: 17,
        max_prompt: gpt3_175b().max_seq as usize,
        ..Default::default()
    };
    let mut tenants = TenantsConfig::parse("alpha/gpt2/weight=2/mix=chat,beta/gpt2/mix=batch")
        .expect("tenant spec");
    tenants.admit_tokens = Some(2048);
    let tenant_tc = TrafficConfig {
        arrivals: ArrivalConfig { qps: 15.0, ..Default::default() },
        requests: 24,
        seed: 29,
        max_prompt: 1024,
        ..Default::default()
    };
    let tenant_reqs = generate_tenant_workload(&tenants, &tenant_tc).expect("tenant workload");
    vec![
        (
            "plain",
            ClusterConfig { telemetry: telemetry(50.0), ..Default::default() },
            2,
            chat_reqs(24, 7),
        ),
        (
            "kv-flash-autoscale",
            ClusterConfig {
                kv_budget: Some(Bytes::gb(2.0)),
                flash: Some(FlashConfig::gb(64.0)),
                autoscale: Some(AutoscaleConfig { target_tokens: 2048, ..Default::default() }),
                telemetry: telemetry(50.0),
                ..Default::default()
            },
            3,
            chat_reqs(32, 11),
        ),
        (
            "faulted-prefix",
            ClusterConfig {
                prefix_cache: Some(PrefixCacheConfig::default()),
                faults: Some(
                    FaultSchedule::parse("crash@0.3:r1:repair0.2,module@0.6:hot", 4)
                        .expect("fault spec"),
                ),
                telemetry: telemetry(50.0),
                ..Default::default()
            },
            4,
            traffic::generate(&agentic).expect("workload"),
        ),
        (
            "tenants",
            ClusterConfig { tenants: Some(tenants), telemetry: telemetry(50.0), ..Default::default() },
            2,
            tenant_reqs,
        ),
        (
            "disaggregated",
            ClusterConfig {
                disaggregate: Some((2, 2)),
                telemetry: telemetry(50.0),
                ..Default::default()
            },
            4,
            fenghuang::coordinator::session_workload(24, 6, 512, 12, Seconds::ms(2.0)),
        ),
    ]
}

fn run_event(cfg: &ClusterConfig, replicas: usize, reqs: &[Request]) -> ClusterReport {
    let mut c = Cluster::fh4(replicas, &gpt3_175b(), cfg.clone()).expect("cluster");
    c.run(reqs.to_vec()).expect("run")
}

fn run_stepping(cfg: &ClusterConfig, replicas: usize, reqs: &[Request]) -> ClusterReport {
    let mut c = Cluster::fh4(replicas, &gpt3_175b(), cfg.clone()).expect("cluster");
    c.run_stepping(reqs.to_vec()).expect("run")
}

fn ledger_bits(l: &StallLedger) -> [u64; 8] {
    [
        l.spans,
        l.queue_wait.value().to_bits(),
        l.prefill_exec.value().to_bits(),
        l.prefix_fetch.value().to_bits(),
        l.swap_stall.value().to_bits(),
        l.decode.value().to_bits(),
        l.ttft_total.value().to_bits(),
        l.e2e_total.value().to_bits(),
    ]
}

/// The full property battery on one finished report.
fn check_report(name: &str, r: &ClusterReport) {
    let tel = r.telemetry.as_ref().unwrap_or_else(|| panic!("{name}: telemetry missing"));

    // Per-span bitwise TTFT conservation: components replay the clock
    // advance exactly, no epsilon.
    for s in &tel.spans {
        assert!(
            s.conserves_ttft(),
            "{name}: span {} ({:?}) does not conserve ttft: queue_end {} + ({} + {}) + {} \
             vs prefill_done {} (ttft {})",
            s.id,
            s.kind,
            s.queue_end.value(),
            s.prefill_compute.value(),
            s.prefix_fetch.value(),
            s.swap_stall.value(),
            s.prefill_done.value(),
            s.ttft.value(),
        );
        assert!(s.finish >= s.prefill_done, "{name}: span {} finishes before TTFT", s.id);
        assert!(s.queue_end >= s.arrival, "{name}: span {} queued before arriving", s.id);
    }

    // Every finishing lifecycle yields exactly one decode-side span.
    let finishing = tel
        .spans
        .iter()
        .filter(|s| s.kind != SpanKind::PrefillHandoff)
        .count() as u64;
    assert_eq!(finishing, r.fleet.completed, "{name}: span count vs completions");

    // The fleet ledger is exactly the per-replica charge/merge fold of
    // the published spans — same grouping, same order, bit-for-bit.
    let mut per: Vec<StallLedger> = vec![StallLedger::default(); r.per_replica.len()];
    for s in &tel.spans {
        per[s.replica].charge(s);
    }
    let mut replay = StallLedger::default();
    for l in &per {
        replay.merge(l);
    }
    assert_eq!(
        ledger_bits(&replay),
        ledger_bits(&tel.ledger),
        "{name}: ledger is not the bitwise fold of its spans"
    );
    assert_eq!(ledger_bits(&tel.ledger), ledger_bits(&r.fleet.ledger), "{name}: fleet ledger");

    // Tenant ledgers partition the spans.
    if let Some(tenants) = &r.tenants {
        let charged: u64 = tenants.iter().map(|t| t.ledger.spans).sum();
        assert_eq!(charged, tel.ledger.spans, "{name}: tenant ledgers must partition spans");
    }

    // Samples are tick-ordered with monotone cumulative counters.
    for w in tel.samples.windows(2) {
        assert!(w[0].at < w[1].at, "{name}: sample ticks must advance");
        assert!(w[0].completed <= w[1].completed, "{name}: completions ran backwards");
        assert!(w[0].tokens_generated <= w[1].tokens_generated, "{name}: tokens ran backwards");
        assert!(w[0].slo_met <= w[1].slo_met, "{name}: slo_met ran backwards");
        assert!(w[0].shed <= w[1].shed && w[0].rejected <= w[1].rejected, "{name}: drops");
    }
    for s in &tel.samples {
        assert!(s.active_replicas >= 1, "{name}: sampled an empty fleet");
        assert!(s.slo_met <= s.slo_total, "{name}: slo_met > slo_total");
        assert!(s.completed <= r.fleet.completed, "{name}: sample outran the run");
    }

    // Rolling attainment: interval-wide windows from t = 0, in [0, 1].
    assert!(!tel.attainment.is_empty(), "{name}: attainment series empty");
    assert_eq!(tel.attainment[0].0, Seconds::ZERO, "{name}: first window starts at 0");
    for &(t, a) in &tel.attainment {
        assert!((0.0..=1.0).contains(&a), "{name}: attainment {a} out of range at {t:?}");
    }

    // Exporters render every span and sample.
    let trace = chrome_trace(tel);
    assert_eq!(trace.matches('{').count(), trace.matches('}').count(), "{name}: trace braces");
    let prefills = tel.spans.iter().filter(|s| s.kind != SpanKind::DecodeInjected).count();
    assert_eq!(
        trace.matches("\"name\": \"prefill\"").count(),
        prefills,
        "{name}: trace must carry one prefill slice per observed prefill"
    );
    let csv = timeseries_csv(tel);
    assert_eq!(csv.lines().count(), tel.samples.len() + 1, "{name}: csv rows vs samples");
}

#[test]
fn spans_conserve_ttft_and_ledger_folds_bitwise_event_core() {
    for (name, cfg, replicas, reqs) in scenarios() {
        check_report(name, &run_event(&cfg, replicas, &reqs));
    }
}

#[test]
fn spans_conserve_ttft_and_ledger_folds_bitwise_stepping_core() {
    for (name, cfg, replicas, reqs) in scenarios() {
        check_report(name, &run_stepping(&cfg, replicas, &reqs));
    }
}

#[test]
fn disaggregated_handoffs_pair_prefill_and_decode_spans() {
    let cfg = ClusterConfig {
        disaggregate: Some((2, 2)),
        telemetry: telemetry(50.0),
        ..Default::default()
    };
    let r = run_event(&cfg, 4, &fenghuang::coordinator::session_workload(24, 6, 512, 12, Seconds::ms(2.0)));
    let tel = r.telemetry.as_ref().unwrap();
    let handoffs: Vec<_> =
        tel.spans.iter().filter(|s| s.kind == SpanKind::PrefillHandoff).collect();
    let injected: Vec<_> =
        tel.spans.iter().filter(|s| s.kind == SpanKind::DecodeInjected).collect();
    assert!(!handoffs.is_empty(), "disaggregated run produced no handoff spans");
    assert_eq!(handoffs.len(), injected.len(), "unpaired handoff spans");
    for d in &injected {
        let p = handoffs
            .iter()
            .find(|p| p.id == d.id)
            .unwrap_or_else(|| panic!("decode span {} has no prefill side", d.id));
        // The decode side carries the measured TTFT over verbatim and
        // reconstructs prefill_done from it.
        assert_eq!(p.ttft.value().to_bits(), d.ttft.value().to_bits(), "ttft handoff {}", d.id);
        assert_eq!(
            (d.arrival + d.ttft).value().to_bits(),
            d.prefill_done.value().to_bits(),
            "injected prefill_done reconstruction {}",
            d.id
        );
        // Prefill attribution lives only on the prefill side.
        assert_eq!(d.prefill_compute, Seconds::ZERO);
        assert_eq!(d.prefix_fetch, Seconds::ZERO);
        assert_eq!(d.swap_stall, Seconds::ZERO);
    }
}

#[test]
fn telemetry_off_publishes_nothing_and_stays_deterministic() {
    let reqs = chat_reqs(24, 7);
    let cfg = ClusterConfig::default();
    let a = run_event(&cfg, 2, &reqs);
    let b = run_event(&cfg, 2, &reqs);
    assert!(a.telemetry.is_none(), "off run must publish no telemetry");
    assert!(a.fleet.ledger.is_zero(), "off run must charge no ledger");
    assert!(!a.summary().contains("stalls ("), "off summary must not grow a stalls line");
    for (x, y) in [
        (a.fleet.clock.value(), b.fleet.clock.value()),
        (a.fleet.ttft.mean_ms(), b.fleet.ttft.mean_ms()),
        (a.fleet.e2e.mean_ms(), b.fleet.e2e.mean_ms()),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "off runs must be bit-identical");
    }
}

#[test]
fn telemetry_on_leaves_every_count_untouched() {
    // The sampling tick may stretch idle replicas' clocks (like
    // autoscale ticks), but what happened — completions, tokens, SLO
    // verdicts, drops — must be exactly the off run's.
    for (name, cfg, replicas, reqs) in scenarios() {
        let on = run_event(&cfg, replicas, &reqs);
        let off_cfg = ClusterConfig { telemetry: None, ..cfg };
        let off = run_event(&off_cfg, replicas, &reqs);
        assert_eq!(on.fleet.completed, off.fleet.completed, "{name}: completed");
        assert_eq!(on.fleet.tokens_generated, off.fleet.tokens_generated, "{name}: tokens");
        assert_eq!(on.fleet.slo_total, off.fleet.slo_total, "{name}: slo_total");
        assert_eq!(on.fleet.slo_met, off.fleet.slo_met, "{name}: slo_met");
        assert_eq!(on.fleet.shed, off.fleet.shed, "{name}: shed");
        assert_eq!(on.fleet.rejected, off.fleet.rejected, "{name}: rejected");
        assert_eq!(
            on.fleet.ttft.mean_ms().to_bits(),
            off.fleet.ttft.mean_ms().to_bits(),
            "{name}: ttft must not shift under observation"
        );
    }
}

#[test]
fn ledger_ttft_total_sums_measured_ttfts() {
    // The headline acceptance property, stated directly: the ledger's
    // TTFT total is the sum of the measured per-request TTFTs — the
    // same numbers the latency metrics recorded.
    let cfg = ClusterConfig { telemetry: telemetry(50.0), ..Default::default() };
    let r = run_event(&cfg, 2, &chat_reqs(24, 7));
    let tel = r.telemetry.as_ref().unwrap();
    let naive: f64 = tel
        .spans
        .iter()
        .filter(|s| s.kind != SpanKind::DecodeInjected)
        .map(|s| s.ttft.value())
        .sum();
    let total = tel.ledger.ttft_total.value();
    assert!(
        (naive - total).abs() <= 1e-9 * naive.max(1.0),
        "ledger ttft_total {total} vs span sum {naive}"
    );
    assert_eq!(tel.ledger.spans as usize, tel.spans.len());
    assert!(tel.ledger.e2e_total >= tel.ledger.ttft_total);
}
