//! Property wall for multi-tenant serving (DESIGN.md §Multi-Tenant):
//! the invariants the admission arbiter and per-tenant accounting must
//! hold on *every* run, checked on both simulation cores.
//!
//! * work conservation — every generated request is admitted, quota-shed
//!   or rejected, per tenant and in the fleet totals;
//! * quotas are never exceeded — a tenant's enqueued work tokens stay at
//!   or under its front-door quota;
//! * weighted share — under DRR a backlogged tenant's admitted tokens
//!   track its weight share to within one round's quantum;
//! * single-tenant passthrough — `TenantsConfig::single` is bit-identical
//!   to a tenants-off fleet on both cores.

use fenghuang::coordinator::tenancy::{
    Admit, Queued, TenantArbiter, TenantArbitration, TenantsConfig,
};
use fenghuang::coordinator::{Cluster, ClusterConfig, ClusterReport, Request};
use fenghuang::models::arch::gpt3_175b;
use fenghuang::traffic::{
    self, generate_tenant_workload, ArrivalConfig, ArrivalPattern, TrafficConfig, WorkloadMix,
};

/// Run the same scenario through the stepping oracle and the event core.
fn run_both(cfg: ClusterConfig, replicas: usize, reqs: Vec<Request>) -> (ClusterReport, ClusterReport) {
    let model = gpt3_175b();
    let mut s = Cluster::fh4(replicas, &model, cfg.clone()).expect("stepping cluster");
    let stepping = s.run_stepping(reqs.clone()).expect("stepping run");
    let mut e = Cluster::fh4(replicas, &model, cfg).expect("event cluster");
    let event = e.run(reqs).expect("event run");
    (stepping, event)
}

fn two_tenant_workload(tenants: &TenantsConfig, requests: usize, seed: u64) -> Vec<Request> {
    let base = TrafficConfig {
        arrivals: ArrivalConfig {
            pattern: ArrivalPattern::Bursty,
            qps: 18.0,
            ..Default::default()
        },
        requests,
        seed,
        max_prompt: 1024,
        slo: None,
        ..Default::default()
    };
    generate_tenant_workload(tenants, &base).expect("tenant workload")
}

#[test]
fn single_tenant_is_bit_identical_to_tenants_off() {
    // `TenantsConfig::single` must be a pure passthrough: same model,
    // no gate, one tenant — every float the fleet reports is bitwise
    // the number the pre-tenancy simulator produced, on both cores.
    let tc = TrafficConfig {
        mix: WorkloadMix::parse("chat+rag").unwrap(),
        requests: 24,
        seed: 41,
        max_prompt: gpt3_175b().max_seq as usize,
        ..Default::default()
    };
    let reqs = traffic::generate(&tc).expect("workload");
    let (off_s, off_e) = run_both(ClusterConfig::default(), 3, reqs.clone());
    let on_cfg = ClusterConfig {
        tenants: Some(TenantsConfig::single(gpt3_175b())),
        ..Default::default()
    };
    let (on_s, on_e) = run_both(on_cfg, 3, reqs);
    for (core, off, on) in [("stepping", &off_s, &on_s), ("event", &off_e, &on_e)] {
        assert_eq!(off.fleet.completed, on.fleet.completed, "{core}: completed");
        assert_eq!(off.fleet.tokens_generated, on.fleet.tokens_generated, "{core}: tokens");
        assert_eq!(off.fleet.shed, on.fleet.shed, "{core}: shed");
        for (k, a, b) in [
            ("clock", off.fleet.clock.value(), on.fleet.clock.value()),
            ("busy", off.fleet.busy.value(), on.fleet.busy.value()),
            ("ttft.mean", off.fleet.ttft.mean_ms(), on.fleet.ttft.mean_ms()),
            ("ttft.p99", off.fleet.ttft.percentile_ms(99.0), on.fleet.ttft.percentile_ms(99.0)),
            ("e2e.mean", off.fleet.e2e.mean_ms(), on.fleet.e2e.mean_ms()),
            ("imbalance", off.imbalance, on.imbalance),
            ("replica_seconds", off.replica_seconds, on.replica_seconds),
            ("gpu_seconds", off.gpu_seconds, on.gpu_seconds),
            ("swap_stall", off.fleet.swap_stall.value(), on.fleet.swap_stall.value()),
        ] {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{core}: `{k}` drifted under single-tenant config — {a} vs {b}"
            );
        }
    }
    // The single-tenant run still reports its (one) tenant.
    let ts = on_s.tenants.as_ref().expect("tenant report");
    assert_eq!(ts.len(), 1);
    assert_eq!(ts[0].completed, on_s.fleet.completed);
    assert_eq!(ts[0].swaps, 0, "a single tenant never cold-starts");
}

#[test]
fn work_is_conserved_per_tenant_and_fleet() {
    // Every generated request must be accounted exactly once: admitted
    // (and, fault-free, completed) or shed at the quota front door. The
    // fleet totals are the sums of the tenant rows.
    let mut tenants =
        TenantsConfig::parse("alpha/gpt2/weight=2/mix=chat,beta/gpt2-xl/quota=9000/mix=batch")
            .expect("spec");
    tenants.admit_tokens = Some(2048);
    let reqs = two_tenant_workload(&tenants, 30, 43);
    let cfg = ClusterConfig { tenants: Some(tenants), ..Default::default() };
    let (s, e) = run_both(cfg, 2, reqs.clone());
    for (core, r) in [("stepping", &s), ("event", &e)] {
        let ts = r.tenants.as_ref().expect("tenant reports");
        assert_eq!(ts.len(), 2, "{core}");
        let mut completed = 0;
        let mut shed = 0;
        for (ti, t) in ts.iter().enumerate() {
            let generated = reqs.iter().filter(|q| q.tenant == ti).count() as u64;
            assert!(generated > 0, "{core}: tenant {ti} got no traffic");
            assert_eq!(
                t.admitted_requests + t.shed_quota,
                generated,
                "{core}: tenant '{}' leaked requests",
                t.name
            );
            assert_eq!(
                t.completed, t.admitted_requests,
                "{core}: tenant '{}' admitted work must complete on a fault-free run",
                t.name
            );
            completed += t.completed;
            shed += t.shed_quota;
        }
        assert_eq!(r.fleet.completed, completed, "{core}: fleet completed ≠ Σ tenants");
        assert_eq!(r.fleet.shed, shed, "{core}: fleet shed ≠ Σ tenant quota sheds");
        assert_eq!(r.fleet.rejected, 0, "{core}: clamped prompts are always admissible");
    }
}

#[test]
fn quota_is_never_exceeded() {
    // The front door sheds *before* enqueueing: a tenant's enqueued work
    // tokens can never pass its quota, and a binding quota must actually
    // shed on this workload.
    let mut tenants =
        TenantsConfig::parse("alpha/gpt2/mix=chat,beta/gpt2-xl/quota=9000/mix=batch")
            .expect("spec");
    tenants.admit_tokens = Some(2048);
    let reqs = two_tenant_workload(&tenants, 30, 47);
    let cfg = ClusterConfig { tenants: Some(tenants), ..Default::default() };
    let (s, e) = run_both(cfg, 2, reqs);
    for (core, r) in [("stepping", &s), ("event", &e)] {
        let ts = r.tenants.as_ref().expect("tenant reports");
        let beta = &ts[1];
        assert!(
            beta.enqueued_tokens <= 9000,
            "{core}: quota exceeded — {} tokens enqueued over a 9000-token quota",
            beta.enqueued_tokens
        );
        assert!(beta.shed_quota > 0, "{core}: quota never bound; pick a tighter one");
        assert!(beta.admitted_tokens <= beta.enqueued_tokens, "{core}");
        // The unlimited tenant is untouched by its neighbour's quota.
        assert_eq!(ts[0].shed_quota, 0, "{core}");
    }
}

#[test]
fn wfq_admitted_share_tracks_weights_within_one_round() {
    // The DRR guarantee, stated on the arbiter itself: with two
    // backlogged tenants at weights 3:1 and requests no larger than the
    // base quantum, any admission prefix keeps tenant A within one
    // round's quantum of 3× tenant B's admitted tokens.
    const WORK: u64 = 1000;
    const EACH: i64 = 40;
    let mut tc = TenantsConfig::parse("a/gpt2/weight=3,b/gpt2").expect("spec");
    tc.quantum = WORK; // one request of credit per round at weight 1
    let mut arb: TenantArbiter<u64> = TenantArbiter::new(&tc);
    for i in 0..EACH as u64 {
        for t in 0..2 {
            arb.enqueue(t, Queued { work: WORK, prompt_len: 800, affinity: i, payload: i });
        }
    }
    let mut seq = Vec::new();
    arb.pump(|t, q| {
        seq.push((t, q.work));
        Admit::Served
    });
    assert_eq!(seq.len(), 2 * EACH as usize, "work conservation: everything admitted");
    assert!(arb.is_empty());
    assert_eq!(arb.queued_tokens(), 0);
    let (mut a, mut b) = (0i64, 0i64);
    let mut remaining = [EACH, EACH];
    // One round hands A a 3×WORK quantum, so the prefix deviation from
    // the exact 3:1 share is bounded by one round plus one request.
    let bound = 3 * WORK as i64 + WORK as i64;
    for (i, &(t, w)) in seq.iter().enumerate() {
        if t == 0 {
            a += w as i64;
        } else {
            b += w as i64;
        }
        remaining[t] -= 1;
        if remaining[0] > 0 && remaining[1] > 0 {
            assert!(
                (a - 3 * b).abs() <= bound,
                "DRR share bound violated: a={a} b={b} after {} admissions",
                i + 1
            );
        }
    }
}

#[test]
fn fifo_head_of_line_blocks_every_tenant_behind_it() {
    // The no-isolation baseline, stated as a property: a blocked FIFO
    // head stalls *all* later arrivals, theirs or not — exactly the
    // failure mode WFQ exists to remove.
    let mut tc = TenantsConfig::parse("a/gpt2,b/gpt2").expect("spec");
    tc.arbitration = TenantArbitration::Fifo;
    let mut arb: TenantArbiter<u64> = TenantArbiter::new(&tc);
    arb.enqueue(1, Queued { work: 4000, prompt_len: 900, affinity: 0, payload: 0 });
    arb.enqueue(0, Queued { work: 100, prompt_len: 50, affinity: 1, payload: 1 });
    let mut offered = Vec::new();
    arb.pump(|t, q| {
        offered.push(t);
        Admit::Blocked(q)
    });
    assert_eq!(offered, vec![1], "FIFO must stop at the blocked head");
    assert_eq!(arb.queued(0), 1, "tenant a's request is stuck behind b's head");
    assert_eq!(arb.queued_tokens(), 4100);
    // WFQ on the same backlog reaches past the stall.
    let mut tc2 = TenantsConfig::parse("a/gpt2,b/gpt2").expect("spec");
    tc2.arbitration = TenantArbitration::Wfq;
    let mut arb2: TenantArbiter<u64> = TenantArbiter::new(&tc2);
    arb2.enqueue(1, Queued { work: 4000, prompt_len: 900, affinity: 0, payload: 0 });
    arb2.enqueue(0, Queued { work: 100, prompt_len: 50, affinity: 1, payload: 1 });
    let mut served = Vec::new();
    arb2.pump(|t, q| {
        if t == 1 {
            Admit::Blocked(q)
        } else {
            served.push(t);
            Admit::Served
        }
    });
    assert_eq!(served, vec![0], "WFQ admits tenant a around b's blocked head");
}
