//! Property-style invariant tests for the paging subsystem, driven by
//! the traffic engine's seeded RNG (`fenghuang::traffic::XorShift`):
//! random operation sequences against `paging::PageTable`, the eviction
//! policies, and `paging::KvPressure` must uphold the orchestrator's
//! core contracts regardless of the op order the RNG happens to draw —
//! capacity is never exceeded, pinned pages never move, and dirty
//! write-back byte accounting stays exact.

use fenghuang::paging::{KvPressure, PageTable, PlacementPolicy, PolicyKind};
use fenghuang::prelude::*;
use fenghuang::trace::TensorId;
use fenghuang::traffic::XorShift;
use std::collections::HashSet;

const PAGE: f64 = 64.0;
const CAP: f64 = 4096.0;

/// Recompute residency from scratch (per-entry sum) — must always agree
/// with the table's running counter.
fn recount(t: &PageTable) -> f64 {
    t.iter().map(|(_, e)| e.resident_bytes().value()).sum()
}

/// Sum of (local, dirty) page bytes for one tensor — the exact bytes an
/// eviction must report as write-back.
fn dirty_resident(t: &PageTable, id: TensorId) -> f64 {
    use fenghuang::paging::page::Residency;
    t.entry(id)
        .map(|e| {
            e.pages
                .iter()
                .filter(|p| p.residency == Residency::Local && p.dirty)
                .map(|p| p.bytes.value())
                .sum()
        })
        .unwrap_or(0.0)
}

/// Capacity-disciplined page-in, mirroring `paging::orchestrate`: evict
/// policy victims until the fetch fits, give up (skip) if the policy
/// legitimately cannot free enough (everything pinned/protected).
/// Returns the write-back bytes observed during eviction.
fn page_in_with_budget(
    table: &mut PageTable,
    pol: &PlacementPolicy,
    id: TensorId,
    now: u64,
    dirty: bool,
    cap: f64,
) -> f64 {
    let missing = table.missing_bytes(id).value();
    let mut wrote_back = 0.0;
    if table.resident_bytes().value() + missing > cap {
        let need = Bytes::new(table.resident_bytes().value() + missing - cap);
        let protect: HashSet<TensorId> = [id].into_iter().collect();
        for victim in pol.victims(table, need, &protect) {
            let expect_dirty = dirty_resident(table, victim);
            let ev = table.evict(victim);
            assert!(
                (ev.dirty_bytes.value() - expect_dirty).abs() < 1e-9,
                "write-back accounting drifted: reported {} vs resident-dirty {}",
                ev.dirty_bytes.value(),
                expect_dirty
            );
            wrote_back += ev.dirty_bytes.value();
        }
    }
    if table.resident_bytes().value() + missing <= cap * (1.0 + 1e-9) {
        table.page_in(id, now, dirty);
    }
    wrote_back
}

#[test]
fn random_ops_never_exceed_capacity_and_accounting_stays_exact() {
    for (seed, kind) in [(1u64, PolicyKind::Lru), (2, PolicyKind::Heat), (3, PolicyKind::MinimalResidency)] {
        let mut rng = XorShift::new(seed);
        let mut table = PageTable::new(Bytes::new(PAGE));
        let pol = PlacementPolicy { kind, ..Default::default() };
        for now in 0..600u64 {
            let id = TensorId(rng.range(0, 23));
            match rng.range(0, 9) {
                // Register / grow (registration alone moves nothing —
                // growth of a resident partial page is the exception the
                // recount catches if miscounted).
                0..=2 => table.register(id, Bytes::new(rng.range(1, 900) as f64)),
                // Fetch under the capacity discipline.
                3..=6 => {
                    if table.contains(id) {
                        let dirty = rng.range(0, 1) == 1;
                        page_in_with_budget(&mut table, &pol, id, now, dirty, CAP);
                    }
                }
                // Spontaneous eviction.
                7 => {
                    let expect = dirty_resident(&table, id);
                    let ev = table.evict(id);
                    assert!((ev.dirty_bytes.value() - expect).abs() < 1e-9);
                }
                // Touch (metadata only; must not move bytes).
                8 => {
                    let before = table.resident_bytes().value();
                    table.touch(id, now);
                    assert_eq!(table.resident_bytes().value(), before);
                }
                // Re-register smaller (documented no-op).
                _ => {
                    if table.contains(id) {
                        table.register(id, Bytes::new(1.0));
                    }
                }
            }
            // Registration growth of a resident partial page can nudge
            // residency over the cap without a fetch; the orchestrator's
            // make-room discipline evicts before the *next* fetch — mirror
            // it here so the invariant below is the steady-state one.
            if table.resident_bytes().value() > CAP {
                let need = Bytes::new(table.resident_bytes().value() - CAP);
                for victim in pol.victims(&table, need, &HashSet::new()) {
                    let expect = dirty_resident(&table, victim);
                    let ev = table.evict(victim);
                    assert!((ev.dirty_bytes.value() - expect).abs() < 1e-9);
                }
            }
            // Invariants, every step:
            let resident = table.resident_bytes().value();
            assert!(
                resident <= CAP * (1.0 + 1e-9),
                "seed {seed} {kind:?}: resident {resident} exceeds capacity {CAP} at op {now}"
            );
            assert!(
                (resident - recount(&table)).abs() < 1e-9,
                "seed {seed} {kind:?}: running counter {resident} vs recount {} at op {now}",
                recount(&table)
            );
            assert!(table.peak_resident().value() + 1e-9 >= resident);
            assert!(table.registered_bytes().value() + 1e-9 >= resident);
        }
    }
}

#[test]
fn pinned_tensors_survive_any_eviction_storm() {
    let mut rng = XorShift::new(42);
    let mut table = PageTable::new(Bytes::new(PAGE));
    let pol = PlacementPolicy { kind: PolicyKind::Lru, ..Default::default() };
    // Pin three tensors and stage them; they must stay fully resident
    // through everything that follows.
    let pinned: Vec<TensorId> = (0..3).map(TensorId).collect();
    let mut pinned_bytes = 0.0;
    for &id in &pinned {
        let sz = rng.range(100, 400) as f64;
        table.register(id, Bytes::new(sz));
        table.page_in(id, 0, false);
        assert_eq!(table.pin(id).value(), sz);
        pinned_bytes += sz;
    }
    assert!(pinned_bytes < CAP / 2.0, "leave room for churn");
    for now in 1..500u64 {
        let id = TensorId(rng.range(3, 20));
        match rng.range(0, 2) {
            0 => table.register(id, Bytes::new(rng.range(1, 700) as f64)),
            1 => {
                if table.contains(id) {
                    page_in_with_budget(&mut table, &pol, id, now, rng.range(0, 1) == 1, CAP);
                }
            }
            _ => {
                table.evict(id);
            }
        }
        // Direct eviction of a pinned tensor is a refused no-op …
        let before = table.resident_bytes();
        assert_eq!(table.evict(pinned[(now % 3) as usize]).pages, 0);
        assert_eq!(table.resident_bytes(), before);
        // … policy victim scans never propose one …
        let victims = pol.victims(&table, Bytes::new(f64::MAX), &HashSet::new());
        for v in &victims {
            assert!(!pinned.contains(v), "policy proposed pinned victim {v:?}");
        }
        // … and every pinned page is still local.
        for &id in &pinned {
            assert_eq!(
                table.missing_bytes(id),
                Bytes::ZERO,
                "pinned tensor {id:?} lost pages at op {now}"
            );
        }
    }
}

#[test]
fn byte_conservation_across_random_walks() {
    // Global ledger: bytes enter local memory via page_in (and resident
    // growth of partial pages at register time) and leave via evict.
    // After any op sequence: total_in − total_evicted == resident.
    let mut rng = XorShift::new(99);
    let mut table = PageTable::new(Bytes::new(PAGE));
    let mut ledger = 0.0f64;
    for now in 0..800u64 {
        let id = TensorId(rng.range(0, 15));
        match rng.range(0, 5) {
            0 | 1 => {
                let before = table.resident_bytes().value();
                table.register(id, Bytes::new(rng.range(1, 500) as f64));
                ledger += table.resident_bytes().value() - before; // partial-page growth
            }
            2 | 3 => {
                let (moved, pages) = table.page_in(id, now, rng.range(0, 1) == 1);
                ledger += moved.value();
                assert!(pages as f64 * PAGE + 1e-9 >= moved.value());
            }
            _ => {
                let ev = table.evict(id);
                ledger -= ev.bytes.value();
                assert!(ev.dirty_bytes <= ev.bytes);
            }
        }
        assert!(
            (ledger - table.resident_bytes().value()).abs() < 1e-9,
            "byte ledger drifted at op {now}: in-out {ledger} vs resident {}",
            table.resident_bytes().value()
        );
    }
}

#[test]
fn kv_pressure_random_footprints_keep_exact_counters() {
    let sys = fh4_15xm(Bandwidth::tbps(4.8));
    let mut rng = XorShift::new(5);
    for _ in 0..20 {
        let budget_gb = rng.range(1, 64) as f64;
        let mut kv = KvPressure::new(Bytes::gb(budget_gb), &sys);
        let mut expect_total = Seconds::ZERO;
        let mut expect_peak = Bytes::ZERO;
        let mut expect_stalled = 0u64;
        for _ in 0..200 {
            let total = Bytes::gb(rng.range(0, 128) as f64);
            let touched = total * rng.next_f64();
            let spill_before = kv.spilled(total);
            let stall = kv.step_stall(total, touched);
            // Spill formula is exact: max(0, total − budget).
            let want_spill = (total.value() - Bytes::gb(budget_gb).value()).max(0.0);
            assert!((spill_before.value() - want_spill).abs() < 1e-6);
            // Stall fires iff something spilled.
            if want_spill > 0.0 {
                assert!(stall > Seconds::ZERO);
                expect_stalled += 1;
            } else {
                assert_eq!(stall, Seconds::ZERO);
            }
            expect_total += stall;
            expect_peak = expect_peak.max(spill_before);
            assert_eq!(kv.steps_stalled, expect_stalled);
            assert!((kv.stall_total.value() - expect_total.value()).abs() < 1e-12);
            assert_eq!(kv.spilled_peak, expect_peak, "peak must be a running max");
        }
    }
}

#[test]
fn kv_pressure_stall_is_monotone_in_touched_bytes() {
    let sys = fh4_15xm(Bandwidth::tbps(4.8));
    let mut rng = XorShift::new(17);
    for _ in 0..100 {
        let budget = Bytes::gb(rng.range(1, 32) as f64);
        let total = Bytes::gb(rng.range(33, 128) as f64); // always over budget
        let small = Bytes::gb(rng.range(1, 16) as f64);
        let large = small * 2.0;
        let mut a = KvPressure::new(budget, &sys);
        let mut b = KvPressure::new(budget, &sys);
        let sa = a.step_stall(total, small);
        let sb = b.step_stall(total, large);
        assert!(
            sb >= sa,
            "touching more spilled KV cannot stall less: {sa:?} vs {sb:?}"
        );
    }
}
