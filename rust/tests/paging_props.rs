//! Property-style invariant tests for the paging subsystem, driven by
//! the traffic engine's seeded RNG (`fenghuang::traffic::XorShift`):
//! random operation sequences against `paging::PageTable`, the eviction
//! policies, and `paging::KvPressure` must uphold the orchestrator's
//! core contracts regardless of the op order the RNG happens to draw —
//! capacity is never exceeded, pinned pages never move, and dirty
//! write-back byte accounting stays exact.

use fenghuang::config::FlashConfig;
use fenghuang::paging::{orchestrate, KvPressure, NmcConfig, PageTable, PlacementPolicy, PolicyKind, Tier};
use fenghuang::prelude::*;
use fenghuang::trace::{Op, OpKind, OpName, TensorId, Trace, WeightRef};
use fenghuang::traffic::XorShift;
use std::collections::HashSet;

const PAGE: f64 = 64.0;
const CAP: f64 = 4096.0;

/// Recompute residency from scratch (per-entry sum) — must always agree
/// with the table's running counter.
fn recount(t: &PageTable) -> f64 {
    t.iter().map(|(_, e)| e.resident_bytes().value()).sum()
}

/// Sum of (local, dirty) page bytes for one tensor — the exact bytes an
/// eviction must report as write-back.
fn dirty_resident(t: &PageTable, id: TensorId) -> f64 {
    use fenghuang::paging::page::Residency;
    t.entry(id)
        .map(|e| {
            e.pages
                .iter()
                .filter(|p| p.residency == Residency::Local && p.dirty)
                .map(|p| p.bytes.value())
                .sum()
        })
        .unwrap_or(0.0)
}

/// Capacity-disciplined page-in, mirroring `paging::orchestrate`: evict
/// policy victims until the fetch fits, give up (skip) if the policy
/// legitimately cannot free enough (everything pinned/protected).
/// Returns the write-back bytes observed during eviction.
fn page_in_with_budget(
    table: &mut PageTable,
    pol: &PlacementPolicy,
    id: TensorId,
    now: u64,
    dirty: bool,
    cap: f64,
) -> f64 {
    let missing = table.missing_bytes(id).value();
    let mut wrote_back = 0.0;
    if table.resident_bytes().value() + missing > cap {
        let need = Bytes::new(table.resident_bytes().value() + missing - cap);
        let protect: HashSet<TensorId> = [id].into_iter().collect();
        for victim in pol.victims(table, need, &protect) {
            let expect_dirty = dirty_resident(table, victim);
            let ev = table.evict(victim);
            assert!(
                (ev.dirty_bytes.value() - expect_dirty).abs() < 1e-9,
                "write-back accounting drifted: reported {} vs resident-dirty {}",
                ev.dirty_bytes.value(),
                expect_dirty
            );
            wrote_back += ev.dirty_bytes.value();
        }
    }
    if table.resident_bytes().value() + missing <= cap * (1.0 + 1e-9) {
        table.page_in(id, now, dirty);
    }
    wrote_back
}

#[test]
fn random_ops_never_exceed_capacity_and_accounting_stays_exact() {
    for (seed, kind) in [(1u64, PolicyKind::Lru), (2, PolicyKind::Heat), (3, PolicyKind::MinimalResidency)] {
        let mut rng = XorShift::new(seed);
        let mut table = PageTable::new(Bytes::new(PAGE));
        let pol = PlacementPolicy { kind, ..Default::default() };
        for now in 0..600u64 {
            let id = TensorId(rng.range(0, 23));
            match rng.range(0, 9) {
                // Register / grow (registration alone moves nothing —
                // growth of a resident partial page is the exception the
                // recount catches if miscounted).
                0..=2 => table.register(id, Bytes::new(rng.range(1, 900) as f64)),
                // Fetch under the capacity discipline.
                3..=6 => {
                    if table.contains(id) {
                        let dirty = rng.range(0, 1) == 1;
                        page_in_with_budget(&mut table, &pol, id, now, dirty, CAP);
                    }
                }
                // Spontaneous eviction.
                7 => {
                    let expect = dirty_resident(&table, id);
                    let ev = table.evict(id);
                    assert!((ev.dirty_bytes.value() - expect).abs() < 1e-9);
                }
                // Touch (metadata only; must not move bytes).
                8 => {
                    let before = table.resident_bytes().value();
                    table.touch(id, now);
                    assert_eq!(table.resident_bytes().value(), before);
                }
                // Re-register smaller (documented no-op).
                _ => {
                    if table.contains(id) {
                        table.register(id, Bytes::new(1.0));
                    }
                }
            }
            // Registration growth of a resident partial page can nudge
            // residency over the cap without a fetch; the orchestrator's
            // make-room discipline evicts before the *next* fetch — mirror
            // it here so the invariant below is the steady-state one.
            if table.resident_bytes().value() > CAP {
                let need = Bytes::new(table.resident_bytes().value() - CAP);
                for victim in pol.victims(&table, need, &HashSet::new()) {
                    let expect = dirty_resident(&table, victim);
                    let ev = table.evict(victim);
                    assert!((ev.dirty_bytes.value() - expect).abs() < 1e-9);
                }
            }
            // Invariants, every step:
            let resident = table.resident_bytes().value();
            assert!(
                resident <= CAP * (1.0 + 1e-9),
                "seed {seed} {kind:?}: resident {resident} exceeds capacity {CAP} at op {now}"
            );
            assert!(
                (resident - recount(&table)).abs() < 1e-9,
                "seed {seed} {kind:?}: running counter {resident} vs recount {} at op {now}",
                recount(&table)
            );
            assert!(table.peak_resident().value() + 1e-9 >= resident);
            assert!(table.registered_bytes().value() + 1e-9 >= resident);
        }
    }
}

#[test]
fn pinned_tensors_survive_any_eviction_storm() {
    let mut rng = XorShift::new(42);
    let mut table = PageTable::new(Bytes::new(PAGE));
    let pol = PlacementPolicy { kind: PolicyKind::Lru, ..Default::default() };
    // Pin three tensors and stage them; they must stay fully resident
    // through everything that follows.
    let pinned: Vec<TensorId> = (0..3).map(TensorId).collect();
    let mut pinned_bytes = 0.0;
    for &id in &pinned {
        let sz = rng.range(100, 400) as f64;
        table.register(id, Bytes::new(sz));
        table.page_in(id, 0, false);
        assert_eq!(table.pin(id).value(), sz);
        pinned_bytes += sz;
    }
    assert!(pinned_bytes < CAP / 2.0, "leave room for churn");
    for now in 1..500u64 {
        let id = TensorId(rng.range(3, 20));
        match rng.range(0, 2) {
            0 => table.register(id, Bytes::new(rng.range(1, 700) as f64)),
            1 => {
                if table.contains(id) {
                    page_in_with_budget(&mut table, &pol, id, now, rng.range(0, 1) == 1, CAP);
                }
            }
            _ => {
                table.evict(id);
            }
        }
        // Direct eviction of a pinned tensor is a refused no-op …
        let before = table.resident_bytes();
        assert_eq!(table.evict(pinned[(now % 3) as usize]).pages, 0);
        assert_eq!(table.resident_bytes(), before);
        // … policy victim scans never propose one …
        let victims = pol.victims(&table, Bytes::new(f64::MAX), &HashSet::new());
        for v in &victims {
            assert!(!pinned.contains(v), "policy proposed pinned victim {v:?}");
        }
        // … and every pinned page is still local.
        for &id in &pinned {
            assert_eq!(
                table.missing_bytes(id),
                Bytes::ZERO,
                "pinned tensor {id:?} lost pages at op {now}"
            );
        }
    }
}

#[test]
fn byte_conservation_across_random_walks() {
    // Global ledger: bytes enter local memory via page_in (and resident
    // growth of partial pages at register time) and leave via evict.
    // After any op sequence: total_in − total_evicted == resident.
    let mut rng = XorShift::new(99);
    let mut table = PageTable::new(Bytes::new(PAGE));
    let mut ledger = 0.0f64;
    for now in 0..800u64 {
        let id = TensorId(rng.range(0, 15));
        match rng.range(0, 5) {
            0 | 1 => {
                let before = table.resident_bytes().value();
                table.register(id, Bytes::new(rng.range(1, 500) as f64));
                ledger += table.resident_bytes().value() - before; // partial-page growth
            }
            2 | 3 => {
                let (moved, pages) = table.page_in(id, now, rng.range(0, 1) == 1);
                ledger += moved.value();
                assert!(pages as f64 * PAGE + 1e-9 >= moved.value());
            }
            _ => {
                let ev = table.evict(id);
                ledger -= ev.bytes.value();
                assert!(ev.dirty_bytes <= ev.bytes);
            }
        }
        assert!(
            (ledger - table.resident_bytes().value()).abs() < 1e-9,
            "byte ledger drifted at op {now}: in-out {ledger} vs resident {}",
            table.resident_bytes().value()
        );
    }
}

#[test]
fn kv_pressure_random_footprints_keep_exact_counters() {
    let sys = fh4_15xm(Bandwidth::tbps(4.8));
    let mut rng = XorShift::new(5);
    for _ in 0..20 {
        let budget_gb = rng.range(1, 64) as f64;
        let mut kv = KvPressure::new(Bytes::gb(budget_gb), &sys);
        let mut expect_total = Seconds::ZERO;
        let mut expect_peak = Bytes::ZERO;
        let mut expect_stalled = 0u64;
        for _ in 0..200 {
            let total = Bytes::gb(rng.range(0, 128) as f64);
            let touched = total * rng.next_f64();
            let spill_before = kv.spilled(total);
            let stall = kv.step_stall(total, touched);
            // Spill formula is exact: max(0, total − budget).
            let want_spill = (total.value() - Bytes::gb(budget_gb).value()).max(0.0);
            assert!((spill_before.value() - want_spill).abs() < 1e-6);
            // Stall fires iff something spilled AND the step actually
            // touched KV bytes — a zero-touch step reads nothing over
            // the fabric, so it cannot stall (it still advances the
            // spill peak, checked below).
            if want_spill > 0.0 && touched.value() > 0.0 {
                assert!(stall > Seconds::ZERO);
                expect_stalled += 1;
            } else {
                assert_eq!(stall, Seconds::ZERO);
            }
            expect_total += stall;
            expect_peak = expect_peak.max(spill_before);
            assert_eq!(kv.steps_stalled, expect_stalled);
            assert!((kv.stall_total.value() - expect_total.value()).abs() < 1e-12);
            assert_eq!(kv.spilled_peak, expect_peak, "peak must be a running max");
        }
    }
}

#[test]
fn victims_are_deterministic_across_insertion_orders() {
    // Tables populated with deliberately duplicated sort keys (same
    // last_use for everyone, two heat bands) in opposite insertion
    // orders: the victim sequence must not depend on HashMap iteration
    // order — neither across tables nor across repeated scans.
    let build = |ids: &[u64]| {
        let mut t = PageTable::new(Bytes::new(PAGE));
        for &id in ids {
            let tid = TensorId(id);
            t.register(tid, Bytes::new(100.0 + (id % 3) as f64));
            t.page_in(tid, 7, false);
            if id % 2 == 0 {
                t.touch(tid, 7);
            }
        }
        t
    };
    let fwd: Vec<u64> = (0..12).collect();
    let rev: Vec<u64> = (0..12).rev().collect();
    let a = build(&fwd);
    let b = build(&rev);
    for kind in PolicyKind::all() {
        let pol = PlacementPolicy { kind, ..Default::default() };
        let need = Bytes::new(600.0);
        let va = pol.victims(&a, need, &HashSet::new());
        assert!(!va.is_empty());
        assert_eq!(
            va,
            pol.victims(&b, need, &HashSet::new()),
            "{kind:?}: victim order depends on insertion order"
        );
        assert_eq!(
            va,
            pol.victims(&a, need, &HashSet::new()),
            "{kind:?}: repeated scans disagree"
        );
    }
    // Demotion scans obey the same discipline.
    let pol = PlacementPolicy::default();
    let mut da = build(&fwd);
    let mut db = build(&rev);
    for t in [&mut da, &mut db] {
        for id in 0..12 {
            t.evict(TensorId(id)); // demotion candidates are non-resident
        }
    }
    let va = pol.demotion_victims(&da, Bytes::new(600.0), &HashSet::new(), None);
    assert!(!va.is_empty());
    assert_eq!(va, pol.demotion_victims(&db, Bytes::new(600.0), &HashSet::new(), None));
}

#[test]
fn home_ledger_conserves_bytes_under_random_walks() {
    // Every registered byte is homed on exactly one tier, no matter the
    // order of register / set_home / remove the RNG draws; the
    // incremental per-tier ledgers must agree with a from-scratch sum.
    let mut rng = XorShift::new(7);
    let mut table = PageTable::new(Bytes::new(PAGE));
    let tiers = [Tier::LocalHbm, Tier::RemotePool, Tier::Flash];
    for now in 0..600u64 {
        let id = TensorId(rng.range(0, 19));
        match rng.range(0, 5) {
            0 | 1 => table.register(id, Bytes::new(rng.range(1, 500) as f64)),
            2 | 3 => {
                let tier = tiers[rng.range(0, 2) as usize];
                table.set_home(id, tier);
                if table.contains(id) {
                    assert_eq!(table.home(id), Some(tier));
                }
            }
            _ => {
                table.remove(id);
                assert!(table.home(id).is_none());
            }
        }
        let homed: f64 = tiers.iter().map(|&t| table.bytes_homed(t).value()).sum();
        assert!(
            (homed - table.registered_bytes().value()).abs() < 1e-9,
            "home ledger drifted at op {now}: homed {homed} vs registered {}",
            table.registered_bytes().value()
        );
        for &t in &tiers {
            assert!(table.bytes_homed(t).value() >= -1e-9, "negative ledger for {t:?}");
        }
    }
}

#[test]
fn flash_orchestration_caps_tiers_and_conserves_bytes() {
    // 40 GB pool + 30 GB flash cannot hold the ~87 GB gpt3/tp4 shard:
    // the remainder must be HBM-homed, no tier may exceed its cap, and
    // the three homes must partition the working set exactly.
    let mut sys = fh4_15xm(Bandwidth::tbps(4.8));
    sys.flash =
        Some(FlashConfig { capacity: Bytes::gb(30.0), bandwidth: Bandwidth::tbps(1.6) });
    let cfg = PagingConfig { pool_budget: Some(Bytes::gb(40.0)), steps: 2, ..Default::default() };
    let r = simulate_paged(&sys, &arch::gpt3_175b(), 8, Phase::Decode { kv_len: 4608 }, &cfg)
        .unwrap();
    assert!(r.pool_homed.as_gb() <= 40.0 * (1.0 + 1e-9), "pool over cap: {}", r.pool_homed.as_gb());
    assert!(r.flash_homed.as_gb() <= 30.0 * (1.0 + 1e-9), "flash over cap: {}", r.flash_homed.as_gb());
    assert!(r.local_homed.value() > 0.0, "the spill past both backing tiers pins in HBM");
    let homed = r.pool_homed + r.flash_homed + r.local_homed;
    assert!(
        (homed.value() - r.working_set.value()).abs() < 1.0,
        "homes must partition the working set: {} vs {}",
        homed.as_gb(),
        r.working_set.as_gb()
    );
    assert!(r.migration.flash_bytes_in.value() > 0.0, "flash bands must stream from flash");
}

#[test]
fn flash_behind_a_roomy_pool_is_bit_identical_to_two_tiers() {
    // With the pool left uncapped nothing ever reaches flash, so every
    // observable — times included — must match the 2-tier run bit for
    // bit, across policies and with the KV stream staged.
    let sys = fh4_15xm(Bandwidth::tbps(4.8));
    let mut fsys = sys.clone();
    fsys.flash = Some(FlashConfig::gb(4096.0));
    for kind in PolicyKind::all() {
        let cfg = PagingConfig {
            policy: PlacementPolicy { kind, page_kv: true, ..Default::default() },
            steps: 3,
            ..Default::default()
        };
        let a = simulate_paged(&sys, &arch::gpt3_175b(), 8, Phase::Decode { kv_len: 4608 }, &cfg)
            .unwrap();
        let b = simulate_paged(&fsys, &arch::gpt3_175b(), 8, Phase::Decode { kv_len: 4608 }, &cfg)
            .unwrap();
        assert_eq!(a.cold_step, b.cold_step, "{kind:?}");
        assert_eq!(a.steady_step, b.steady_step, "{kind:?}");
        assert_eq!(a.exposed, b.exposed, "{kind:?}");
        assert_eq!(a.paging_busy, b.paging_busy, "{kind:?}");
        assert_eq!(a.peak_local, b.peak_local, "{kind:?}");
        assert_eq!(a.migration.bytes_in, b.migration.bytes_in, "{kind:?}");
        assert_eq!(a.migration.time_in, b.migration.time_in, "{kind:?}");
        assert_eq!(a.migration.bytes_out, b.migration.bytes_out, "{kind:?}");
        assert_eq!(a.evictions, b.evictions, "{kind:?}");
        assert_eq!(b.migration.flash_pages_in, 0, "{kind:?}");
        assert_eq!(b.migration.demotions + b.migration.promotions, 0, "{kind:?}");
        assert_eq!(b.flash_homed, Bytes::ZERO, "{kind:?}");
    }
}

#[test]
fn nmc_never_elides_a_flash_homed_gather() {
    // A toy trace of two embedding gathers. In-pool NMC elides both
    // page-ins; with a pool too small for the second table, that table
    // homes on flash, out of the gather engine's reach — the op must
    // fall back to paging the table in at the media rate.
    let embed = |id: u64| Op {
        op: OpName::Embed,
        layer: 0,
        kind: OpKind::Memory,
        flops: Flops::ZERO,
        read_bytes: Bytes::mib(8.0),
        write_bytes: Bytes::mib(8.0),
        weights: vec![WeightRef { id: TensorId(id), bytes: Bytes::gb(4.0) }],
        m_tokens: 1024.0,
        shard_cols: 1024.0,
        comm_payload: Bytes::ZERO,
        scratch_bytes: Bytes::mib(16.0),
        kv_stream_bytes: Bytes::ZERO,
    };
    let tr = Trace {
        model: "toy-embed".into(),
        phase: Phase::Decode { kv_len: 1 },
        tp: 4,
        batch: 8,
        ops: vec![embed(1), embed(2)],
    };
    let sys = fh4_15xm(Bandwidth::tbps(4.8));
    let cfg = PagingConfig { nmc: NmcConfig { enabled: true }, steps: 2, ..Default::default() };
    let pool_only = orchestrate(&sys, &tr, &cfg).unwrap();
    assert_eq!(pool_only.nmc_offloads, 4, "2 ops × 2 steps gather in-pool");
    assert_eq!(pool_only.migration.bytes_in, Bytes::ZERO, "NMC elides page-in entirely");
    let mut fsys = sys.clone();
    fsys.flash = Some(FlashConfig::gb(64.0));
    let split = PagingConfig { pool_budget: Some(Bytes::gb(6.0)), ..cfg };
    let r = orchestrate(&fsys, &tr, &split).unwrap();
    assert!(
        r.nmc_offloads < pool_only.nmc_offloads,
        "a flash-homed table must not gather in-pool: {} offloads",
        r.nmc_offloads
    );
    assert!(r.migration.flash_bytes_in.value() > 0.0, "the flash-homed table pages in");
}

#[test]
fn kv_pressure_flash_spill_orders_and_prices_the_tiers() {
    // 3-tier KV pressure: spill fills the pool slice first, only the
    // overflow past it lands on flash, and a slower flash tier can only
    // stall more. Without flash overflow the two configs are bitwise
    // identical — the flash bandwidth must be unreachable then.
    let mk = |tbps: f64| {
        let mut s = fh4_15xm(Bandwidth::tbps(4.8));
        s.remote_capacity = Bytes::gb(8.0);
        s.flash =
            Some(FlashConfig { capacity: Bytes::gb(256.0), bandwidth: Bandwidth::tbps(tbps) });
        s
    };
    let budget = Bytes::gb(4.0);
    let mut fast = KvPressure::new(budget, &mk(1.6));
    let mut slow = KvPressure::new(budget, &mk(0.4));
    let mut rng = XorShift::new(23);
    let mut expect_flash_peak = 0.0f64;
    for _ in 0..200 {
        let total = Bytes::gb(rng.range(0, 64) as f64);
        let touched = total * 0.5;
        let s_fast = fast.step_stall(total, touched);
        let s_slow = slow.step_stall(total, touched);
        let spill = (total.value() - budget.value()).max(0.0);
        let flash_spill = (spill - Bytes::gb(8.0).value()).max(0.0).min(spill);
        expect_flash_peak = expect_flash_peak.max(flash_spill);
        assert!(
            (fast.flash_spilled_peak.value() - expect_flash_peak).abs() < 1e-6,
            "flash spill peak drifted"
        );
        if flash_spill > 0.0 && touched.value() > 0.0 {
            assert!(s_slow > s_fast, "slower flash must stall more");
        } else {
            assert_eq!(s_slow, s_fast, "no flash overflow → flash bandwidth unreachable");
        }
    }
}

#[test]
fn kv_pressure_stall_is_monotone_in_touched_bytes() {
    let sys = fh4_15xm(Bandwidth::tbps(4.8));
    let mut rng = XorShift::new(17);
    for _ in 0..100 {
        let budget = Bytes::gb(rng.range(1, 32) as f64);
        let total = Bytes::gb(rng.range(33, 128) as f64); // always over budget
        let small = Bytes::gb(rng.range(1, 16) as f64);
        let large = small * 2.0;
        let mut a = KvPressure::new(budget, &sys);
        let mut b = KvPressure::new(budget, &sys);
        let sa = a.step_stall(total, small);
        let sb = b.step_stall(total, large);
        assert!(
            sb >= sa,
            "touching more spilled KV cannot stall less: {sa:?} vs {sb:?}"
        );
    }
}
