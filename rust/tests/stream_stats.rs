//! Statistical tests for the streaming percentile accumulator
//! (DESIGN.md §Event-Core): below [`STREAMING_THRESHOLD`] the stat is
//! bitwise the historical exact nearest-rank path (golden snapshots
//! depend on it); above it, the log-spaced histogram must estimate
//! p50/p95/p99 within 1 % relative error against the exact sorted
//! reference on exponential, bimodal and heavy-tailed samples, and
//! `merge()` of streaming accumulators must match the pooled stat
//! within the same tolerance.

use fenghuang::coordinator::metrics::{LatencyStat, STREAMING_THRESHOLD};
use fenghuang::traffic::XorShift;
use fenghuang::units::{percentile_nearest_rank, Seconds};

fn record_all(stat: &mut LatencyStat, samples: &[f64]) {
    for &ms in samples {
        stat.record(Seconds::ms(ms));
    }
}

fn exact_percentile(samples: &[f64], p: f64) -> f64 {
    let mut s = samples.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_nearest_rank(&s, p)
}

fn rel_err(est: f64, exact: f64) -> f64 {
    if exact == 0.0 {
        est.abs()
    } else {
        (est - exact).abs() / exact.abs()
    }
}

fn assert_streaming_close(name: &str, samples: &[f64]) {
    assert!(samples.len() > STREAMING_THRESHOLD, "{name}: must engage streaming");
    let mut stat = LatencyStat::default();
    record_all(&mut stat, samples);
    assert!(stat.is_streaming(), "{name}: past threshold ⇒ streaming");
    assert_eq!(stat.count(), samples.len());
    for p in [50.0, 95.0, 99.0] {
        let exact = exact_percentile(samples, p);
        let est = stat.percentile_ms(p);
        assert!(
            rel_err(est, exact) < 0.01,
            "{name}: p{p} streaming {est} vs exact {exact} ({:.3} % off)",
            100.0 * rel_err(est, exact)
        );
    }
    // The running max and running mean are exact, not binned.
    let max = samples.iter().copied().fold(0.0, f64::max);
    assert_eq!(stat.max_ms().to_bits(), max.to_bits(), "{name}: max is exact");
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    assert!(rel_err(stat.mean_ms(), mean) < 1e-12, "{name}: mean is a running sum");
}

fn exponential_samples(n: usize, seed: u64, mean_ms: f64) -> Vec<f64> {
    let mut rng = XorShift::new(seed);
    (0..n).map(|_| rng.exp(mean_ms)).collect()
}

fn bimodal_samples(n: usize, seed: u64) -> Vec<f64> {
    // 70 % fast mode around 2–3 ms, 30 % slow mode around 50–60 ms —
    // the shape of a fleet with a saturated minority.
    let mut rng = XorShift::new(seed);
    (0..n)
        .map(|_| {
            if rng.next_f64() < 0.7 {
                2.0 + rng.next_f64()
            } else {
                50.0 + 10.0 * rng.next_f64()
            }
        })
        .collect()
}

fn heavy_tail_samples(n: usize, seed: u64) -> Vec<f64> {
    // Pareto(x_m = 1 ms, α = 1.5): infinite variance, the tail shape
    // that breaks fixed-linear-bin histograms.
    let mut rng = XorShift::new(seed);
    (0..n)
        .map(|_| {
            let u = rng.next_f64();
            1.0 / (1.0 - u).powf(1.0 / 1.5)
        })
        .collect()
}

#[test]
fn exact_path_is_bitwise_nearest_rank_below_threshold() {
    let samples = exponential_samples(10_000, 3, 7.5);
    let mut stat = LatencyStat::default();
    record_all(&mut stat, &samples);
    assert!(!stat.is_streaming(), "below threshold stays exact");
    assert_eq!(stat.count(), samples.len());
    for p in [0.0, 10.0, 50.0, 90.0, 95.0, 99.0, 99.9, 100.0] {
        assert_eq!(
            stat.percentile_ms(p).to_bits(),
            exact_percentile(&samples, p).to_bits(),
            "exact path must be bitwise nearest-rank at p{p}"
        );
    }
    // At exactly the threshold the stat still holds raw samples: the
    // golden snapshots never see a histogram estimate.
    let mut edge = LatencyStat::default();
    record_all(&mut edge, &exponential_samples(STREAMING_THRESHOLD, 4, 7.5));
    assert!(!edge.is_streaming());
}

#[test]
fn streaming_percentiles_within_one_percent_on_exponential() {
    assert_streaming_close(
        "exponential",
        &exponential_samples(STREAMING_THRESHOLD + 15_000, 11, 12.0),
    );
}

#[test]
fn streaming_percentiles_within_one_percent_on_bimodal() {
    assert_streaming_close("bimodal", &bimodal_samples(STREAMING_THRESHOLD + 15_000, 12));
}

#[test]
fn streaming_percentiles_within_one_percent_on_heavy_tail() {
    assert_streaming_close("heavy-tail", &heavy_tail_samples(STREAMING_THRESHOLD + 15_000, 13));
}

#[test]
fn merge_of_exact_stats_below_threshold_stays_bitwise() {
    // merge() of two small stats is sample concatenation: identical to
    // one stat that recorded the concatenated sequence.
    let a = exponential_samples(5_000, 21, 4.0);
    let b = bimodal_samples(5_000, 22);
    let mut merged = LatencyStat::default();
    record_all(&mut merged, &a);
    let mut other = LatencyStat::default();
    record_all(&mut other, &b);
    merged.merge(&other);
    assert!(!merged.is_streaming());
    let mut pooled = LatencyStat::default();
    record_all(&mut pooled, &a);
    record_all(&mut pooled, &b);
    assert_eq!(merged.count(), pooled.count());
    for p in [50.0, 95.0, 99.0, 100.0] {
        assert_eq!(merged.percentile_ms(p).to_bits(), pooled.percentile_ms(p).to_bits());
    }
    assert_eq!(merged.mean_ms().to_bits(), pooled.mean_ms().to_bits());
}

#[test]
fn merge_crossing_threshold_matches_pooled_within_tolerance() {
    // Two exact halves whose union exceeds the threshold: the merge
    // engages streaming and must still track the pooled exact stats.
    let a = exponential_samples(STREAMING_THRESHOLD / 2 + 5_000, 31, 6.0);
    let b = heavy_tail_samples(STREAMING_THRESHOLD / 2 + 5_000, 32);
    let mut merged = LatencyStat::default();
    record_all(&mut merged, &a);
    let mut other = LatencyStat::default();
    record_all(&mut other, &b);
    assert!(!merged.is_streaming() && !other.is_streaming());
    merged.merge(&other);
    assert!(merged.is_streaming(), "crossing the threshold engages streaming");
    let pooled: Vec<f64> = a.iter().chain(&b).copied().collect();
    assert_eq!(merged.count(), pooled.len());
    for p in [50.0, 95.0, 99.0] {
        let exact = exact_percentile(&pooled, p);
        let est = merged.percentile_ms(p);
        assert!(
            rel_err(est, exact) < 0.01,
            "merged p{p}: {est} vs pooled {exact}"
        );
    }
    let max = pooled.iter().copied().fold(0.0, f64::max);
    assert_eq!(merged.max_ms().to_bits(), max.to_bits());
}

#[test]
fn merge_of_two_streaming_stats_matches_pooled_within_tolerance() {
    let a = bimodal_samples(STREAMING_THRESHOLD + 2_000, 41);
    let b = exponential_samples(STREAMING_THRESHOLD + 2_000, 42, 9.0);
    let mut sa = LatencyStat::default();
    record_all(&mut sa, &a);
    let mut sb = LatencyStat::default();
    record_all(&mut sb, &b);
    assert!(sa.is_streaming() && sb.is_streaming());
    sa.merge(&sb);
    let pooled: Vec<f64> = a.iter().chain(&b).copied().collect();
    assert_eq!(sa.count(), pooled.len());
    for p in [50.0, 95.0, 99.0] {
        let exact = exact_percentile(&pooled, p);
        let est = sa.percentile_ms(p);
        assert!(
            rel_err(est, exact) < 0.01,
            "two-streaming merge p{p}: {est} vs pooled {exact}"
        );
    }
    let mean = pooled.iter().sum::<f64>() / pooled.len() as f64;
    assert!(rel_err(sa.mean_ms(), mean) < 1e-12);
}
