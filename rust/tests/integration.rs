//! Cross-module integration + property tests.
//!
//! The offline build has no proptest crate, so properties are exercised
//! with a deterministic xorshift generator over many random cases —
//! same spirit: each test states an invariant and hammers it with
//! randomised inputs.

use fenghuang::config::{baseline8, fh4_15xm, fh4_20xm};
use fenghuang::coordinator::{synthetic_workload, Batcher, Scheduler, SimBackend};
use fenghuang::fabric::collectives::group;
use fenghuang::fabric::tab::TabPool;
use fenghuang::models::arch::{self, eval_models};
use fenghuang::sim::{self, PrefetchPolicy};
use fenghuang::trace::Phase;
use fenghuang::units::{Bandwidth, Seconds};
use std::sync::Arc;

/// Deterministic xorshift64* PRNG for property loops.
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }
    fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.next() % (hi - lo).max(1)
    }
    fn f32(&mut self) -> f32 {
        (self.next() >> 40) as f32 / (1u64 << 24) as f32 - 0.5
    }
}

// ---------------------------------------------------------------------------
// Fabric properties.
// ---------------------------------------------------------------------------

#[test]
fn prop_tab_write_read_roundtrip_random_regions() {
    let mut rng = Rng::new(42);
    let pool = TabPool::new(1 << 16, 7, 129); // deliberately odd striping
    for case in 0..200 {
        let len = rng.range(1, 4000) as usize;
        let region = pool.alloc(len).unwrap();
        let data: Vec<f32> = (0..len).map(|_| rng.f32()).collect();
        pool.write(region, 0, &data).unwrap();
        // Random sub-read must match the slice.
        let off = rng.range(0, len as u64) as usize;
        let sub = rng.range(0, (len - off) as u64 + 1) as usize;
        let got = pool.read(region, off, sub).unwrap();
        assert_eq!(got, &data[off..off + sub], "case {case} len {len} off {off}");
        pool.free(region);
    }
    assert_eq!(pool.free_elems(), pool.capacity(), "all regions returned");
}

#[test]
fn prop_allocator_never_hands_out_overlapping_regions() {
    let mut rng = Rng::new(7);
    let pool = TabPool::new(1 << 14, 4, 64);
    let mut live: Vec<fenghuang::fabric::Region> = Vec::new();
    for _ in 0..500 {
        if rng.next() % 3 != 0 || live.is_empty() {
            let len = rng.range(1, 1 << 10) as usize;
            if let Ok(r) = pool.alloc(len) {
                for other in &live {
                    let a = r.offset..r.offset + r.len;
                    let b = other.offset..other.offset + other.len;
                    assert!(
                        a.end <= b.start || b.end <= a.start,
                        "overlap: {r:?} vs {other:?}"
                    );
                }
                live.push(r);
            }
        } else {
            let idx = rng.range(0, live.len() as u64) as usize;
            pool.free(live.swap_remove(idx));
        }
    }
    for r in live.drain(..) {
        pool.free(r);
    }
    assert_eq!(pool.free_elems(), pool.capacity());
}

#[test]
fn prop_collectives_match_scalar_reduction_random_worlds() {
    let mut rng = Rng::new(99);
    for case in 0..10 {
        let world = rng.range(2, 7) as usize;
        let len = rng.range(1, 2048) as usize;
        let seeds: Vec<u64> = (0..world).map(|_| rng.next()).collect();
        let pool = Arc::new(TabPool::new(1 << 18, 8, 128));
        let comms = group(pool, world);
        let outs: Vec<Vec<f32>> = comms
            .into_iter()
            .zip(seeds.clone())
            .map(|(mut c, seed)| {
                std::thread::spawn(move || {
                    let mut r = Rng::new(seed);
                    let data: Vec<f32> = (0..len).map(|_| r.f32()).collect();
                    c.all_reduce(&data).unwrap()
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect();
        // Scalar oracle.
        let mut expect = vec![0f32; len];
        for seed in seeds {
            let mut r = Rng::new(seed);
            for e in expect.iter_mut() {
                *e += r.f32();
            }
        }
        for (rank, out) in outs.iter().enumerate() {
            for i in 0..len {
                assert!(
                    (out[i] - expect[i]).abs() < 1e-4,
                    "case {case} rank {rank} elem {i}: {} vs {}",
                    out[i],
                    expect[i]
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Simulator properties.
// ---------------------------------------------------------------------------

#[test]
fn prop_makespan_at_least_busy_time() {
    for m in eval_models() {
        for kv in [512u64, 4608, 16384] {
            let sys = fh4_15xm(Bandwidth::tbps(4.8));
            let r = sim::simulate(&sys, &m, 8, Phase::Decode { kv_len: kv }).unwrap();
            assert!(
                r.total + Seconds::ns(1.0) >= r.compute_busy,
                "{}@{kv}: makespan {} < busy {}",
                m.name,
                r.total.as_ms(),
                r.compute_busy.as_ms()
            );
            assert!(r.exposed_prefetch >= Seconds::ZERO);
            assert!(r.peak_local.value() > 0.0);
        }
    }
}

#[test]
fn prop_huge_remote_bandwidth_hides_all_prefetch() {
    // As remote bandwidth → ∞ the paging stream vanishes from the
    // critical path: exposure ≈ 0 and total → compute-side total.
    let sys = fh4_15xm(Bandwidth::tbps(10_000.0));
    let r = sim::simulate(&sys, &arch::gpt3_175b(), 8, Phase::Decode { kv_len: 4608 }).unwrap();
    assert!(
        r.exposure_frac() < 0.02,
        "exposure {:.4} should vanish at infinite bandwidth",
        r.exposure_frac()
    );
}

#[test]
fn prop_ttft_monotone_in_prompt_and_tpot_monotone_in_kv() {
    let sys = baseline8();
    let m = arch::qwen3_235b();
    let mut last = Seconds::ZERO;
    for prompt in [256u64, 1024, 4096, 16384] {
        let r = sim::simulate(&sys, &m, 8, Phase::Prefill { prompt_len: prompt }).unwrap();
        assert!(r.total > last, "TTFT must grow with prompt");
        last = r.total;
    }
    let mut last = Seconds::ZERO;
    for kv in [256u64, 2048, 16384, 65536] {
        let r = sim::simulate(&sys, &m, 8, Phase::Decode { kv_len: kv }).unwrap();
        assert!(r.total >= last, "TPOT must not shrink with context");
        last = r.total;
    }
}

#[test]
fn prop_wider_window_never_hurts_much() {
    // Deeper lookahead can only add overlap opportunity; allow 1% noise.
    let sys = fh4_15xm(Bandwidth::tbps(4.0));
    for m in eval_models() {
        let mut last = f64::INFINITY;
        for w in [1usize, 2, 4, 10, 20] {
            let p = PrefetchPolicy { window: w, ..Default::default() };
            let r = sim::simulate_with_policy(&sys, &m, 8, Phase::Decode { kv_len: 4608 }, &p)
                .unwrap();
            assert!(
                r.total.value() <= last * 1.01,
                "{}: w={w} slower than narrower window",
                m.name
            );
            last = last.min(r.total.value());
        }
    }
}

#[test]
fn prop_fh_local_memory_an_order_below_baseline() {
    // The abstract's "up to 93% local memory capacity reduction".
    for m in eval_models() {
        let base =
            sim::simulate(&baseline8(), &m, 8, Phase::Decode { kv_len: 5120 }).unwrap();
        let fh = sim::simulate(&fh4_15xm(Bandwidth::tbps(4.8)), &m, 8, Phase::Decode { kv_len: 5120 })
            .unwrap();
        let reduction = 1.0 - fh.peak_local.value() / base.peak_local.value();
        assert!(
            reduction > 0.80,
            "{}: local-memory reduction only {:.1}%",
            m.name,
            reduction * 100.0
        );
    }
}

#[test]
fn prop_fh4_20xm_never_slower_than_15xm() {
    for m in eval_models() {
        for tbps in [4.0, 4.8, 6.4] {
            let r15 = sim::run_workload(&fh4_15xm(Bandwidth::tbps(tbps)), &m, 8, 4096, 1024)
                .unwrap();
            let r20 = sim::run_workload(&fh4_20xm(Bandwidth::tbps(tbps)), &m, 8, 4096, 1024)
                .unwrap();
            assert!(
                r20.e2e.value() <= r15.e2e.value() * 1.001,
                "{}@{tbps}: 2.0xM slower than 1.5xM",
                m.name
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Coordinator properties.
// ---------------------------------------------------------------------------

#[test]
fn prop_scheduler_conserves_tokens_random_workloads() {
    let mut rng = Rng::new(2024);
    for case in 0..5 {
        let n = rng.range(5, 30) as usize;
        let backend = SimBackend::new(fh4_15xm(Bandwidth::tbps(4.8)), arch::gpt3_175b(), 8);
        let mut sched = Scheduler::new(backend, Batcher::new(8, 64, 1 << 20));
        let gen = rng.range(1, 32) as usize;
        let reqs = synthetic_workload(n, 1024, gen, Seconds::ms(rng.range(1, 100) as f64));
        sched.submit_all(reqs);
        sched.run_to_completion().unwrap();
        assert_eq!(sched.metrics.completed as usize, n, "case {case}");
        let total_generated: usize = sched.responses.iter().map(|r| r.generated).sum();
        assert_eq!(sched.metrics.tokens_generated as usize, total_generated);
        for r in &sched.responses {
            assert!(r.ttft <= r.total, "TTFT ≤ E2E");
            assert_eq!(r.generated, gen);
        }
    }
}

#[test]
fn fh4_serving_beats_baseline8_on_qa_throughput() {
    // End-to-end coordinator view of the paper's claim: half the GPUs,
    // comparable-or-better service. Same workload on both systems.
    let workload = || synthetic_workload(24, 2048, 64, Seconds::ms(5.0));
    let run = |sys| {
        let backend = SimBackend::new(sys, arch::qwen3_235b(), 8);
        let mut sched = Scheduler::new(backend, Batcher::new(8, 64, 1 << 20));
        sched.submit_all(workload());
        sched.run_to_completion().unwrap();
        sched.metrics.clone()
    };
    let base = run(baseline8());
    let fh = run(fh4_15xm(Bandwidth::tbps(4.8)));
    assert!(
        fh.throughput_tokens_per_s() > 0.9 * base.throughput_tokens_per_s(),
        "FH4 throughput {:.1} vs baseline {:.1} tok/s",
        fh.throughput_tokens_per_s(),
        base.throughput_tokens_per_s()
    );
}

// ---------------------------------------------------------------------------
// Active tensor paging (DESIGN.md §Paging).
// ---------------------------------------------------------------------------

#[test]
fn paged_orchestrator_hits_table43_band_with_finite_steps() {
    use fenghuang::paging::{simulate_paged, PagingConfig};
    let sys = fh4_15xm(Bandwidth::tbps(4.8));
    let r = simulate_paged(
        &sys,
        &arch::gpt3_175b(),
        8,
        Phase::Decode { kv_len: 4608 },
        &PagingConfig::default(),
    )
    .unwrap();
    assert!(r.steady_step.value() > 0.0 && r.steady_step.value().is_finite());
    assert!(r.cold_step >= r.steady_step);
    // Table 4.3 band: the minimal-residency default needs an order of
    // magnitude less local memory than the 144 GB Baseline8 HBM.
    assert!(r.peak_local.as_gb() < 20.0, "peak {} GB", r.peak_local.as_gb());
    assert!(r.capacity_reduction_vs(fenghuang::units::Bytes::gb(144.0)) > 0.85);
}

#[test]
fn prop_paged_capacity_stall_tradeoff_is_monotone() {
    // The acceptance property of the capacity sweep: shrinking the local
    // budget never speeds the steady step up (LRU, GPT-3 decode).
    use fenghuang::paging::{simulate_paged, PagingConfig, PlacementPolicy, PolicyKind};
    use fenghuang::units::Bytes;
    let sys = fh4_15xm(Bandwidth::tbps(4.8));
    let full_cfg = PagingConfig {
        policy: PlacementPolicy { kind: PolicyKind::Lru, ..Default::default() },
        ..Default::default()
    };
    let full = simulate_paged(&sys, &arch::gpt3_175b(), 8, Phase::Decode { kv_len: 4608 }, &full_cfg)
        .unwrap();
    let ws = full.working_set.as_gb();
    let mut prev = f64::INFINITY;
    for frac in [0.10, 0.25, 0.60, 1.0] {
        let cfg = PagingConfig {
            local_budget: Some(Bytes::gb(ws * frac)),
            policy: PlacementPolicy { kind: PolicyKind::Lru, ..Default::default() },
            ..Default::default()
        };
        let r = simulate_paged(&sys, &arch::gpt3_175b(), 8, Phase::Decode { kv_len: 4608 }, &cfg)
            .unwrap();
        let step = r.steady_step.value();
        assert!(
            step <= prev * 1.001,
            "budget {frac} of WS: step {step} regressed above {prev}"
        );
        assert!(step + 1e-12 >= full.steady_step.value() * 0.999, "capped can't beat uncapped");
        prev = step;
    }
}
