//! Property tests for the shared prefix-KV cache
//! (DESIGN.md §Prefix-Cache): random seeded insert/lookup sequences
//! against small pool capacities, with the trie's structural and ledger
//! invariants re-checked after every operation.
//!
//! Invariants pinned:
//! * longest-prefix lookup never returns more tokens than were inserted
//!   for any prompt sharing that prefix;
//! * eviction never orphans children, never breaks parent/child links,
//!   and keeps the byte ledger exactly `live extents × bytes/token`,
//!   within the capacity derived from the node's pool tier;
//! * hit/insert/evict counters obey their conservation laws across
//!   arbitrary operation interleavings.

use fenghuang::config::fh4_15xm;
use fenghuang::coordinator::{PrefixCache, PrefixCacheConfig};
use fenghuang::models::arch::gpt3_175b;
use fenghuang::models::memory;
use fenghuang::paging::{PolicyKind, TierModel};
use fenghuang::traffic::XorShift;
use fenghuang::units::Bandwidth;

fn sys() -> fenghuang::config::SystemConfig {
    fh4_15xm(Bandwidth::tbps(4.8))
}

fn cache(cfg: PrefixCacheConfig) -> PrefixCache {
    PrefixCache::new(cfg, &sys(), &gpt3_175b()).expect("cache")
}

/// Random prompt over a tiny alphabet with a session-style shared head:
/// prompts of one "session" share their first `head` tokens, so lookups
/// actually traverse shared chains.
fn prompt(rng: &mut XorShift, session: u64, head: usize, len: usize) -> Vec<i32> {
    let mut p = Vec::with_capacity(len);
    for i in 0..len {
        if i < head {
            p.push(((session * 131 + i as u64 * 7) % 17) as i32 + 1);
        } else {
            p.push((rng.range(1, 17)) as i32);
        }
    }
    p
}

/// Longest common prefix of two token slices.
fn lcp(a: &[i32], b: &[i32]) -> usize {
    a.iter().zip(b).take_while(|(x, y)| x == y).count()
}

#[test]
fn random_sequences_preserve_invariants_and_lookup_bounds() {
    for seed in [1u64, 7, 42] {
        for policy in [PolicyKind::Lru, PolicyKind::Heat] {
            let bpt = memory::kv_cache_bytes(&gpt3_175b(), 1, 1);
            // Tight capacity (~40 extents) so eviction churns constantly.
            let mut c = cache(PrefixCacheConfig {
                capacity: Some(bpt * 40.0),
                policy,
                max_tokens: 64,
                ..Default::default()
            });
            let mut rng = XorShift::new(seed);
            // Everything ever inserted, truncated to the indexed depth.
            let mut inserted: Vec<Vec<i32>> = Vec::new();
            for step in 0..300 {
                let session = rng.range(0, 5);
                let head = rng.range(2, 12) as usize;
                let len = rng.range(head as u64 + 1, 30) as usize;
                let p = prompt(&mut rng, session, head, len);
                if rng.next_f64() < 0.5 {
                    let before = c.stats.lookups;
                    let hit = c.lookup(&p);
                    assert_eq!(c.stats.lookups, before + 1, "every probe is counted");
                    // The lookup can never know more of this prompt than
                    // the longest inserted chain sharing its prefix —
                    // eviction only ever shrinks what is reachable.
                    let bound = inserted
                        .iter()
                        .map(|q| lcp(&p, q))
                        .max()
                        .unwrap_or(0)
                        .min(p.len() - 1)
                        .min(64);
                    assert!(
                        hit.tokens <= bound,
                        "seed {seed} step {step}: lookup returned {} tokens, \
                         upper bound {bound}",
                        hit.tokens
                    );
                    if hit.tokens > 0 {
                        assert!(hit.fetch.value() > 0.0, "hits charge a fetch");
                        assert!(
                            (hit.bytes.value() - c.bytes_per_token().value() * hit.tokens as f64)
                                .abs()
                                < 1e-6,
                            "hit bytes must match the extent ledger"
                        );
                    }
                } else {
                    let replica = rng.range(0, 3) as usize;
                    c.insert(&p, replica);
                    inserted.push(p[..p.len().min(64)].to_vec());
                }
                c.check_invariants()
                    .unwrap_or_else(|e| panic!("seed {seed} step {step} [{policy:?}]: {e}"));
                assert!(c.held_bytes() <= c.capacity(), "capacity breached at step {step}");
            }
            assert!(c.stats.evicted_tokens > 0, "tight capacity must churn");
            // Structural hit guarantee: a chain inserted last is
            // path-protected during its own insert, so an immediate
            // re-probe must traverse it.
            let probe: Vec<i32> = (1..=12).collect();
            c.insert(&probe, 0);
            assert_eq!(c.lookup(&probe).tokens, 11);
            assert!(c.stats.hits > 0, "shared heads must produce hits");
            c.check_invariants().unwrap();
        }
    }
}

#[test]
fn byte_accounting_is_exact_against_the_tier_model() {
    // Capacity derived from the pool share must equal the TierModel's
    // remote capacity times the share — the cache and the paging layer
    // must agree on what the pool is.
    let share = 0.125;
    let c = cache(PrefixCacheConfig { pool_share: share, ..Default::default() });
    let pool = TierModel::from_system(&sys())
        .pool()
        .capacity
        .expect("TAB node has a pool");
    assert!(
        (c.capacity().value() - pool.value() * share).abs() < 1e-6,
        "cache capacity {} vs tier share {}",
        c.capacity().value(),
        pool.value() * share
    );
    // Ledger exactness: insert k extents, held == k × bytes/token to the
    // bit (all quantities are integer-valued f64s below 2^53).
    let mut c = cache(PrefixCacheConfig::default());
    let p: Vec<i32> = (1..=37).collect();
    c.insert(&p, 0);
    assert_eq!(c.entries(), 37);
    assert_eq!(c.held_bytes().value(), c.bytes_per_token().value() * 37.0);
    // Re-inserting is idempotent on the ledger.
    c.insert(&p, 1);
    assert_eq!(c.entries(), 37);
    assert_eq!(c.held_bytes().value(), c.bytes_per_token().value() * 37.0);
    c.check_invariants().unwrap();
}

#[test]
fn counters_are_conserved_across_churn() {
    let bpt = memory::kv_cache_bytes(&gpt3_175b(), 1, 1);
    let mut c = cache(PrefixCacheConfig {
        capacity: Some(bpt * 25.0),
        max_tokens: 32,
        ..Default::default()
    });
    let mut rng = XorShift::new(99);
    let mut lookups = 0u64;
    for _ in 0..200 {
        let session = rng.range(0, 3);
        let p = prompt(&mut rng, session, 6, 20);
        c.insert(&p, 0);
        let _ = c.lookup(&p);
        lookups += 1;
    }
    assert_eq!(c.stats.lookups, lookups);
    assert!(c.stats.hits <= c.stats.lookups);
    assert!(c.stats.hit_tokens <= c.stats.probed_tokens);
    assert_eq!(
        c.stats.inserted_tokens - c.stats.evicted_tokens,
        c.entries() as u64,
        "inserted − evicted must equal the live extent count"
    );
    assert!(c.stats.bytes_peak <= c.capacity());
    assert!(c.held_bytes() <= c.stats.bytes_peak);
    c.check_invariants().unwrap();
}

#[test]
fn heat_policy_protects_reused_chains() {
    // One hot session probed repeatedly, many cold one-shot prompts:
    // under the heat policy the hot chain must survive the churn.
    let bpt = memory::kv_cache_bytes(&gpt3_175b(), 1, 1);
    let mut c = cache(PrefixCacheConfig {
        capacity: Some(bpt * 30.0),
        policy: PolicyKind::Heat,
        max_tokens: 32,
        ..Default::default()
    });
    let hot: Vec<i32> = (1..=10).collect();
    c.insert(&hot, 0);
    let mut rng = XorShift::new(5);
    for i in 0..40 {
        // Cold traffic with a disjoint token alphabet.
        let cold: Vec<i32> = (0..12).map(|j| 100 + i * 13 + j).collect();
        c.insert(&cold, 1);
        // Keep the hot chain hot.
        assert_eq!(c.lookup(&hot).tokens, 9, "hot chain evicted at round {i}");
        let _ = rng.next_u64();
        c.check_invariants().unwrap();
    }
    assert!(c.stats.evicted_tokens > 0, "cold churn must evict");
}
