//! Golden regression tests: fixed-seed cluster runs whose fleet metrics
//! are pinned to a checked-in snapshot at 1e-9 relative tolerance, so a
//! silent cost-model drift (a changed latency constant, a reordered
//! charge, an accidental f32 truncation) fails tier-1 instead of
//! quietly skewing every experiment downstream.
//!
//! Snapshot lifecycle: `rust/tests/golden_values.txt` is written on the
//! first run in an environment where it does not exist (the test passes
//! and prints a notice — commit the file), and enforced thereafter.
//! `FH_GOLDEN_REGEN=1 cargo test -q --test golden` regenerates it after
//! an *intentional* cost-model change.

use fenghuang::coordinator::{
    AutoscaleConfig, Cluster, ClusterConfig, ClusterReport, PrefixCacheConfig, TenantsConfig,
};
use fenghuang::fabric::contention::{ContentionConfig, ContentionMode};
use fenghuang::models::arch::gpt3_175b;
use fenghuang::traffic::{
    self, generate_tenant_workload, ArrivalConfig, ArrivalPattern, TrafficConfig, WorkloadMix,
};
use fenghuang::units::Bytes;
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::PathBuf;

fn snapshot_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden_values.txt")
}

fn workload_cfg(requests: usize) -> TrafficConfig {
    TrafficConfig {
        arrivals: ArrivalConfig {
            pattern: ArrivalPattern::Bursty,
            qps: 10.0,
            ..Default::default()
        },
        mix: WorkloadMix::parse("chat+rag").unwrap(),
        requests,
        seed: 7,
        max_prompt: gpt3_175b().max_seq as usize,
        ..Default::default()
    }
}

fn run(replicas: usize, cfg: ClusterConfig, requests: usize) -> ClusterReport {
    let mut cluster = Cluster::fh4(replicas, &gpt3_175b(), cfg).expect("cluster");
    let reqs = traffic::generate(&workload_cfg(requests)).expect("workload");
    cluster.run(reqs).expect("run")
}

/// The pinned observables of one run, in a stable order.
fn observe(prefix: &str, r: &ClusterReport, out: &mut BTreeMap<String, f64>) {
    let m = |k: &str, v: f64| (format!("{prefix}.{k}"), v);
    for (k, v) in [
        m("completed", r.fleet.completed as f64),
        m("makespan_s", r.makespan().value()),
        m("p95_ttft_ms", r.fleet.ttft.percentile_ms(95.0)),
        m("p95_tpot_ms", r.fleet.tpot.percentile_ms(95.0)),
        m("paging_stall_s", r.fleet.paging_stall.value()),
        m("imbalance", r.imbalance),
        m("slo_attainment", r.fleet.slo_attainment()),
        m("goodput_tok_s", r.fleet.goodput_tokens_per_s()),
        m("replica_seconds", r.replica_seconds),
    ] {
        out.insert(k, v);
    }
    if let Some(pc) = &r.prefix_cache {
        for (k, v) in [
            m("prefix_hit_rate", pc.hit_rate),
            m("prefix_hit_tokens", pc.hit_tokens as f64),
            m("prefill_tokens_saved", r.fleet.prefill_tokens_saved as f64),
            m("prefix_fetch_ms", r.fleet.prefix_fetch.as_ms()),
            m("prefix_pool_peak_gb", pc.pool_bytes_peak.as_gb()),
        ] {
            out.insert(k, v);
        }
    }
    if let Some(ts) = &r.tenants {
        for t in ts {
            let p = |k: &str, v: f64| (format!("{prefix}.tenant.{}.{k}", t.name), v);
            for (k, v) in [
                p("completed", t.completed as f64),
                p("slo_attainment", t.slo_attainment()),
                p("goodput_tokens", t.goodput_tokens as f64),
                p("p95_ttft_ms", t.ttft.percentile_ms(95.0)),
                p("swaps", t.swaps as f64),
                p("cold_start_total_ms", t.cold_start_total.as_ms()),
                p("pool_bytes_held_gb", t.pool_bytes_held.as_gb()),
                p("shed_quota", t.shed_quota as f64),
            ] {
                out.insert(k, v);
            }
        }
    }
    if let Some(fr) = &r.fabric {
        for (k, v) in [
            m("fabric_transfers", fr.transfers as f64),
            m("fabric_bytes_gb", fr.bytes.as_gb()),
            m("fabric_busy_frac", fr.busy_frac),
            m("fabric_queue_p99_ms", fr.queue_p99.as_ms()),
            m("fabric_queue_total_ms", fr.queue_total.as_ms()),
            m("fabric_imbalance", fr.module_imbalance),
            m("fabric_wait_ms", r.fleet.fabric_wait.as_ms()),
        ] {
            out.insert(k, v);
        }
    }
}

/// Every metric the snapshot pins, from fresh runs.
fn current_metrics() -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    // Single replica under KV pressure: exercises the paging-stall path.
    let single = run(
        1,
        ClusterConfig { kv_budget: Some(Bytes::gb(2.0)), ..Default::default() },
        24,
    );
    assert!(single.fleet.paging_stall.value() > 0.0, "KV budget must bind");
    observe("single", &single, &mut out);
    // 4-replica elastic fleet: routing, autoscaling, SLO scoring.
    let quad = run(
        4,
        ClusterConfig {
            autoscale: Some(AutoscaleConfig { target_tokens: 1024, ..Default::default() }),
            ..Default::default()
        },
        32,
    );
    observe("quad", &quad, &mut out);
    // The `serve --qps` path end to end: diurnal mixed traffic with the
    // default SLO and front-door shedding on a 2-replica fleet.
    let serve_tc = TrafficConfig {
        arrivals: ArrivalConfig {
            pattern: ArrivalPattern::Diurnal,
            qps: 12.0,
            ..Default::default()
        },
        mix: WorkloadMix::parse("chat+agentic+batch").expect("mix"),
        requests: 32,
        seed: 13,
        max_prompt: gpt3_175b().max_seq as usize,
        ..Default::default()
    };
    let mut fleet = Cluster::fh4(
        2,
        &gpt3_175b(),
        ClusterConfig { shed_tokens: Some(12_000), ..Default::default() },
    )
    .expect("cluster");
    let serve = fleet.run(traffic::generate(&serve_tc).expect("workload")).expect("run");
    observe("serve", &serve, &mut out);
    // Shared prefix cache over agentic sessions: the cross-replica reuse
    // path (DESIGN.md §Prefix-Cache) pinned from day one.
    let prefix_tc = TrafficConfig {
        mix: WorkloadMix::parse("agentic").expect("mix"),
        requests: 32,
        seed: 17,
        max_prompt: gpt3_175b().max_seq as usize,
        ..Default::default()
    };
    let mut fleet = Cluster::fh4(
        2,
        &gpt3_175b(),
        ClusterConfig { prefix_cache: Some(PrefixCacheConfig::default()), ..Default::default() },
    )
    .expect("cluster");
    let prefix = fleet.run(traffic::generate(&prefix_tc).expect("workload")).expect("run");
    assert!(
        prefix.fleet.prefill_tokens_saved > 0,
        "agentic sessions must reuse the shared prefix"
    );
    observe("prefix", &prefix, &mut out);
    // Shared-fabric arbitration (DESIGN.md §Fabric-Contention): the same
    // agentic reuse path with the pool modelled as a finite resource —
    // pins the booking algorithm (window walk, residual maths, queueing
    // attribution) against silent drift.
    let contention_tc = TrafficConfig {
        mix: WorkloadMix::parse("agentic").expect("mix"),
        requests: 32,
        seed: 19,
        max_prompt: gpt3_175b().max_seq as usize,
        ..Default::default()
    };
    let mut fleet = Cluster::fh4(
        4,
        &gpt3_175b(),
        ClusterConfig {
            prefix_cache: Some(PrefixCacheConfig::default()),
            contention: ContentionConfig {
                mode: ContentionMode::Shared,
                ..Default::default()
            },
            ..Default::default()
        },
    )
    .expect("cluster");
    let contention =
        fleet.run(traffic::generate(&contention_tc).expect("workload")).expect("run");
    assert!(
        contention.fabric.as_ref().is_some_and(|fr| fr.transfers > 0),
        "the contended run must book fabric transfers"
    );
    observe("contention", &contention, &mut out);
    // Multi-tenant serving over one shared pool (DESIGN.md
    // §Multi-Tenant): three tenants on two replicas under WFQ with a
    // binding gate, so the pin covers per-tenant SLO attainment and
    // goodput, the DRR admission walk, and the cold-start swap path —
    // the homeless third tenant must page its model in through the pool.
    let mut tenant_cfg = TenantsConfig::parse(
        "alpha/gpt2/weight=3/mix=chat,beta/gpt2-xl/mix=batch,gamma/gpt2/mix=rag",
    )
    .expect("tenant spec");
    tenant_cfg.admit_tokens = Some(2048);
    let tenant_tc = TrafficConfig {
        arrivals: ArrivalConfig {
            pattern: ArrivalPattern::Bursty,
            qps: 16.0,
            ..Default::default()
        },
        requests: 27,
        seed: 23,
        max_prompt: 1024,
        ..Default::default()
    };
    let reqs = generate_tenant_workload(&tenant_cfg, &tenant_tc).expect("workload");
    let mut fleet = Cluster::fh4(
        2,
        &gpt3_175b(),
        ClusterConfig { tenants: Some(tenant_cfg), ..Default::default() },
    )
    .expect("cluster");
    let tenant_run = fleet.run(reqs).expect("run");
    let ts = tenant_run.tenants.as_ref().expect("tenant reports");
    assert!(
        ts.iter().any(|t| t.swaps > 0),
        "the homeless tenant must cold-start at least once"
    );
    observe("tenants", &tenant_run, &mut out);
    out
}

fn render(metrics: &BTreeMap<String, f64>) -> String {
    let mut s = String::from(
        "# Golden fleet metrics (fixed seed 7; see rust/tests/golden.rs).\n\
         # Regenerate intentionally with FH_GOLDEN_REGEN=1 cargo test -q --test golden\n",
    );
    for (k, v) in metrics {
        writeln!(s, "{k} {v:.17e}").unwrap();
    }
    s
}

fn parse(text: &str) -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (k, v) = line.split_once(' ').expect("golden line is `key value`");
        out.insert(k.to_string(), v.trim().parse().expect("golden value parses"));
    }
    out
}

#[test]
fn cluster_runs_are_bitwise_deterministic() {
    // The engine contract the snapshot relies on: same seed, same fleet
    // → identical metrics within 1e-9 relative (in practice, bit-equal).
    let a = current_metrics();
    let b = current_metrics();
    assert_eq!(a.len(), b.len());
    for (k, va) in &a {
        let vb = b[k];
        let tol = 1e-9 * va.abs().max(1.0);
        assert!(
            (va - vb).abs() <= tol,
            "{k} differs across identical runs: {va} vs {vb}"
        );
    }
}

#[test]
fn fleet_metrics_match_golden_snapshot() {
    let path = snapshot_path();
    let current = current_metrics();
    let regen = std::env::var_os("FH_GOLDEN_REGEN").is_some();
    if regen || !path.exists() {
        std::fs::write(&path, render(&current)).expect("write golden snapshot");
        eprintln!(
            "golden: {} snapshot at {} — commit it to pin the cost model",
            if regen { "regenerated" } else { "created" },
            path.display()
        );
        return;
    }
    let golden = parse(&std::fs::read_to_string(&path).expect("read golden snapshot"));
    let mut drift = Vec::new();
    for (k, want) in &golden {
        match current.get(k) {
            None => drift.push(format!("{k}: present in snapshot, missing from run")),
            Some(got) => {
                let tol = 1e-9 * want.abs().max(1e-9);
                if (got - want).abs() > tol {
                    drift.push(format!(
                        "{k}: golden {want:.12e} vs current {got:.12e} \
                         (rel {:.3e})",
                        (got - want).abs() / want.abs().max(1e-300)
                    ));
                }
            }
        }
    }
    for k in current.keys() {
        if !golden.contains_key(k) {
            drift.push(format!(
                "{k}: new metric not in snapshot (regenerate with FH_GOLDEN_REGEN=1)"
            ));
        }
    }
    assert!(
        drift.is_empty(),
        "cost model drifted from the golden snapshot \
         (FH_GOLDEN_REGEN=1 to accept intentionally):\n{}",
        drift.join("\n")
    );
}
