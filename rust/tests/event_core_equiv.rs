//! Differential equivalence harness: the event-driven cluster core
//! (`Cluster::run`, DESIGN.md §Event-Core) against the tick-stepping
//! oracle (`Cluster::run_stepping`), on seeded scenarios covering every
//! cluster feature, asserting *bit*-identical fleet metrics — not
//! tolerance-close: `f64::to_bits` equality on every latency aggregate,
//! clock, integral and ledger observable. Any reordered floating-point
//! add, skipped sync point or drifted router observation fails here
//! before it can silently skew an experiment.

use fenghuang::coordinator::{
    session_workload, AutoscaleConfig, Cluster, ClusterConfig, ClusterReport, PrefixCacheConfig,
    Request,
};
use fenghuang::coordinator::metrics::LatencyStat;
use fenghuang::coordinator::tenancy::{TenantArbitration, TenantsConfig};
use fenghuang::fabric::contention::{ContentionConfig, ContentionMode};
use fenghuang::faults::FaultSchedule;
use fenghuang::models::arch::gpt3_175b;
use fenghuang::traffic::{
    self, generate_tenant_workload, ArrivalConfig, ArrivalPattern, TrafficConfig, WorkloadMix,
};
use fenghuang::units::{Bytes, Seconds};

/// Collect every f64 observable of a report as (label, bits).
fn bits(label: &str, v: f64, out: &mut Vec<(String, u64)>) {
    out.push((label.to_string(), v.to_bits()));
}

fn stat_bits(prefix: &str, s: &LatencyStat, out: &mut Vec<(String, u64)>) {
    bits(&format!("{prefix}.count"), s.count() as f64, out);
    bits(&format!("{prefix}.mean_ms"), s.mean_ms(), out);
    for p in [50.0, 95.0, 99.0] {
        bits(&format!("{prefix}.p{p}"), s.percentile_ms(p), out);
    }
    bits(&format!("{prefix}.max_ms"), s.max_ms(), out);
}

fn observe(r: &ClusterReport) -> Vec<(String, u64)> {
    let mut out = Vec::new();
    let f = &r.fleet;
    for (k, v) in [
        ("completed", f.completed as f64),
        ("rejected", f.rejected as f64),
        ("shed", f.shed as f64),
        ("tokens_generated", f.tokens_generated as f64),
        ("slo_total", f.slo_total as f64),
        ("slo_met", f.slo_met as f64),
        ("goodput_tokens", f.goodput_tokens as f64),
        ("prefill_tokens", f.prefill_tokens as f64),
        ("prefill_tokens_saved", f.prefill_tokens_saved as f64),
        ("prefix_fetch", f.prefix_fetch.value()),
        ("clock", f.clock.value()),
        ("busy", f.busy.value()),
        ("paging_stall", f.paging_stall.value()),
        ("fabric_wait", f.fabric_wait.value()),
        ("swap_stall", f.swap_stall.value()),
        ("imbalance", r.imbalance),
        ("handoffs", r.handoffs as f64),
        ("handoff_time", r.handoff_time.value()),
        ("kv_spilled_peak", r.kv_spilled_peak.value()),
        ("flash_spilled_peak", r.flash_spilled_peak.value()),
        ("replica_seconds", r.replica_seconds),
        ("gpu_seconds", r.gpu_seconds),
        ("elastic", r.elastic as u8 as f64),
        ("scale_events", r.scale_events.len() as f64),
    ] {
        bits(k, v, &mut out);
    }
    for (i, &(t, n)) in r.scale_events.iter().enumerate() {
        bits(&format!("scale[{i}].t"), t.value(), &mut out);
        bits(&format!("scale[{i}].n"), n as f64, &mut out);
    }
    stat_bits("ttft", &f.ttft, &mut out);
    stat_bits("tpot", &f.tpot, &mut out);
    stat_bits("e2e", &f.e2e, &mut out);
    for (i, p) in r.per_replica.iter().enumerate() {
        out.push((format!("r[{i}].name:{}", p.name), 0));
        out.push((format!("r[{i}].role:{:?}", p.role), 0));
        for (k, v) in [
            ("completed", p.completed as f64),
            ("handoffs", p.handoffs as f64),
            ("routed_tokens", p.routed_tokens as f64),
            ("busy", p.busy.value()),
            ("clock", p.clock.value()),
            ("utilization", p.utilization),
            ("paging_stall", p.paging_stall.value()),
            ("kv_spilled_peak", p.kv_spilled_peak.value()),
        ] {
            bits(&format!("r[{i}].{k}"), v, &mut out);
        }
    }
    if let Some(pc) = &r.prefix_cache {
        for (k, v) in [
            ("lookups", pc.lookups as f64),
            ("hits", pc.hits as f64),
            ("hit_tokens", pc.hit_tokens as f64),
            ("inserted_tokens", pc.inserted_tokens as f64),
            ("evicted_tokens", pc.evicted_tokens as f64),
            ("entries", pc.entries as f64),
            ("pool_bytes_held", pc.pool_bytes_held.value()),
            ("pool_bytes_peak", pc.pool_bytes_peak.value()),
            ("capacity", pc.capacity.value()),
            ("hit_rate", pc.hit_rate),
            ("token_hit_rate", pc.token_hit_rate),
        ] {
            bits(&format!("pc.{k}"), v, &mut out);
        }
    } else {
        out.push(("pc.none".to_string(), 0));
    }
    if let Some(fr) = &r.fabric {
        for (k, v) in [
            ("ports", fr.ports as f64),
            ("modules", fr.modules as f64),
            ("window", fr.window.value()),
            ("transfers", fr.transfers as f64),
            ("bytes", fr.bytes.value()),
            ("busy", fr.busy.value()),
            ("horizon", fr.horizon.value()),
            ("busy_frac", fr.busy_frac),
            ("queue_mean", fr.queue_mean.value()),
            ("queue_p50", fr.queue_p50.value()),
            ("queue_p95", fr.queue_p95.value()),
            ("queue_p99", fr.queue_p99.value()),
            ("queue_max", fr.queue_max.value()),
            ("queue_total", fr.queue_total.value()),
            ("serialization", fr.serialization.value()),
            ("module_imbalance", fr.module_imbalance),
        ] {
            bits(&format!("fab.{k}"), v, &mut out);
        }
        for (i, b) in fr.module_bytes.iter().enumerate() {
            bits(&format!("fab.module[{i}]"), b.value(), &mut out);
        }
    } else {
        out.push(("fab.none".to_string(), 0));
    }
    if let Some(ft) = &r.faults {
        for (k, v) in [
            ("crashes", ft.crashes as f64),
            ("rejoins", ft.rejoins as f64),
            ("module_failures", ft.module_failures as f64),
            ("link_degrades", ft.link_degrades as f64),
            ("requeued", ft.requests_requeued as f64),
            ("reprefilled", ft.requests_reprefilled as f64),
            ("tokens_lost", ft.tokens_lost as f64),
            ("bytes_invalidated", ft.bytes_invalidated.value()),
            ("extents_invalidated", ft.extents_invalidated as f64),
            ("first_fault", ft.first_fault.map(|s| s.value()).unwrap_or(-1.0)),
            ("baseline_attainment", ft.baseline_attainment),
            ("dip_attainment", ft.dip_attainment),
            ("slo_dip", ft.slo_dip),
            ("recovery_time", ft.recovery_time.map(|s| s.value()).unwrap_or(-1.0)),
            ("recovered", ft.recovered as u8 as f64),
            ("goodput_lost", ft.goodput_lost_tokens),
        ] {
            bits(&format!("faults.{k}"), v, &mut out);
        }
    } else {
        out.push(("faults.none".to_string(), 0));
    }
    if let Some(ts) = &r.tenants {
        for (i, t) in ts.iter().enumerate() {
            out.push((format!("tenant[{i}].name:{}", t.name), 0));
            out.push((format!("tenant[{i}].model:{}", t.model), 0));
            for (k, v) in [
                ("weight", t.weight),
                ("admitted_requests", t.admitted_requests as f64),
                ("admitted_tokens", t.admitted_tokens as f64),
                ("enqueued_tokens", t.enqueued_tokens as f64),
                ("shed_quota", t.shed_quota as f64),
                ("completed", t.completed as f64),
                ("tokens_generated", t.tokens_generated as f64),
                ("slo_total", t.slo_total as f64),
                ("slo_met", t.slo_met as f64),
                ("goodput_tokens", t.goodput_tokens as f64),
                ("swaps", t.swaps as f64),
                ("cold_start_total", t.cold_start_total.value()),
                ("pool_bytes_held", t.pool_bytes_held.value()),
            ] {
                bits(&format!("tenant[{i}].{k}"), v, &mut out);
            }
            ledger_bits(&format!("tenant[{i}].ledger"), &t.ledger, &mut out);
            stat_bits(&format!("tenant[{i}].ttft"), &t.ttft, &mut out);
            stat_bits(&format!("tenant[{i}].cold_start"), &t.cold_start, &mut out);
        }
    } else {
        out.push(("tenants.none".to_string(), 0));
    }
    if let Some(tel) = &r.telemetry {
        bits("tel.interval", tel.interval.value(), &mut out);
        ledger_bits("tel.ledger", &tel.ledger, &mut out);
        bits("tel.spans", tel.spans.len() as f64, &mut out);
        for (i, s) in tel.spans.iter().enumerate() {
            out.push((format!("tel.span[{i}].kind:{:?}", s.kind), s.id));
            bits(&format!("tel.span[{i}].replica"), s.replica as f64, &mut out);
            bits(&format!("tel.span[{i}].tenant"), s.tenant as f64, &mut out);
            for (k, v) in [
                ("arrival", s.arrival.value()),
                ("queue_end", s.queue_end.value()),
                ("prefill_compute", s.prefill_compute.value()),
                ("prefix_fetch", s.prefix_fetch.value()),
                ("swap_stall", s.swap_stall.value()),
                ("prefill_done", s.prefill_done.value()),
                ("ttft", s.ttft.value()),
                ("finish", s.finish.value()),
                ("generated", s.generated as f64),
            ] {
                bits(&format!("tel.span[{i}].{k}"), v, &mut out);
            }
        }
        bits("tel.samples", tel.samples.len() as f64, &mut out);
        for (i, s) in tel.samples.iter().enumerate() {
            for (k, v) in [
                ("at", s.at.value()),
                ("active_replicas", s.active_replicas as f64),
                ("routed_tokens", s.routed_tokens as f64),
                ("pending", s.pending as f64),
                ("completed", s.completed as f64),
                ("tokens_generated", s.tokens_generated as f64),
                ("shed", s.shed as f64),
                ("rejected", s.rejected as f64),
                ("slo_total", s.slo_total as f64),
                ("slo_met", s.slo_met as f64),
                ("pool_bytes", s.pool_bytes),
                ("fabric_busy", s.fabric_busy.value()),
            ] {
                bits(&format!("tel.sample[{i}].{k}"), v, &mut out);
            }
        }
        for (i, &(t, a)) in tel.attainment.iter().enumerate() {
            bits(&format!("tel.att[{i}].t"), t.value(), &mut out);
            bits(&format!("tel.att[{i}].a"), a, &mut out);
        }
    } else {
        out.push(("telemetry.none".to_string(), 0));
    }
    out
}

fn ledger_bits(prefix: &str, l: &fenghuang::telemetry::StallLedger, out: &mut Vec<(String, u64)>) {
    for (k, v) in [
        ("spans", l.spans as f64),
        ("queue_wait", l.queue_wait.value()),
        ("prefill_exec", l.prefill_exec.value()),
        ("prefix_fetch", l.prefix_fetch.value()),
        ("swap_stall", l.swap_stall.value()),
        ("decode", l.decode.value()),
        ("ttft_total", l.ttft_total.value()),
        ("e2e_total", l.e2e_total.value()),
    ] {
        bits(&format!("{prefix}.{k}"), v, out);
    }
}

/// Run the same (cluster-config, workload) pair through both cores and
/// demand bit-identical reports.
fn assert_equivalent(scenario: &str, cfg: ClusterConfig, replicas: usize, reqs: Vec<Request>) {
    let model = gpt3_175b();
    let mut stepping = Cluster::fh4(replicas, &model, cfg.clone()).expect("stepping cluster");
    let oracle = stepping.run_stepping(reqs.clone()).expect("stepping run");
    let mut event = Cluster::fh4(replicas, &model, cfg).expect("event cluster");
    let fast = event.run(reqs).expect("event run");
    let a = observe(&oracle);
    let b = observe(&fast);
    assert_eq!(a.len(), b.len(), "{scenario}: observable sets differ in shape");
    for ((ka, va), (kb, vb)) in a.iter().zip(&b) {
        assert_eq!(ka, kb, "{scenario}: observable order diverged");
        assert_eq!(
            va, vb,
            "{scenario}: `{ka}` differs — stepping {} vs event {}",
            f64::from_bits(*va),
            f64::from_bits(*vb),
        );
    }
}

fn traffic_reqs(tc: &TrafficConfig) -> Vec<Request> {
    traffic::generate(tc).expect("workload")
}

#[test]
fn equiv_kv_pressure_bursty() {
    // Bursty chat+rag against a binding per-replica KV budget: paging
    // stalls are charged inside decode costs, where any divergence in
    // step sequencing would compound.
    let tc = TrafficConfig {
        arrivals: ArrivalConfig {
            pattern: ArrivalPattern::Bursty,
            qps: 10.0,
            ..Default::default()
        },
        mix: WorkloadMix::parse("chat+rag").unwrap(),
        requests: 24,
        seed: 7,
        max_prompt: gpt3_175b().max_seq as usize,
        ..Default::default()
    };
    assert_equivalent(
        "kv-pressure",
        ClusterConfig { kv_budget: Some(Bytes::gb(2.0)), ..Default::default() },
        2,
        traffic_reqs(&tc),
    );
}

#[test]
fn equiv_elastic_diurnal() {
    // Diurnal chat on an autoscaled fleet: tick/arrival interleaving,
    // the replica-seconds integral and the scale-event log must match
    // to the bit.
    let tc = TrafficConfig {
        arrivals: ArrivalConfig {
            pattern: ArrivalPattern::Diurnal,
            qps: 10.0,
            diurnal_period: Seconds::new(8.0),
            diurnal_floor: 0.05,
            ..Default::default()
        },
        mix: WorkloadMix::parse("chat").unwrap(),
        requests: 48,
        seed: 7,
        max_prompt: 4096,
        slo: None,
        ..Default::default()
    };
    assert_equivalent(
        "elastic-diurnal",
        ClusterConfig {
            autoscale: Some(AutoscaleConfig { target_tokens: 2048, ..Default::default() }),
            ..Default::default()
        },
        4,
        traffic_reqs(&tc),
    );
}

#[test]
fn equiv_prefix_cache_agentic() {
    // Agentic sessions through the shared prefix cache: lookup/insert
    // ordering, cached-prefix discounts and fetch stalls.
    let tc = TrafficConfig {
        mix: WorkloadMix::parse("agentic").unwrap(),
        requests: 32,
        seed: 17,
        max_prompt: gpt3_175b().max_seq as usize,
        slo: None,
        ..Default::default()
    };
    assert_equivalent(
        "prefix-agentic",
        ClusterConfig {
            prefix_cache: Some(PrefixCacheConfig::default()),
            ..Default::default()
        },
        4,
        traffic_reqs(&tc),
    );
}

#[test]
fn equiv_fabric_contention() {
    // Prefix traffic through the arbitrated fabric: every booking's
    // (time, bytes, port, id) tuple must be issued in the same order or
    // the ledger's queueing delays diverge.
    let tc = TrafficConfig {
        mix: WorkloadMix::parse("agentic").unwrap(),
        requests: 32,
        seed: 19,
        max_prompt: gpt3_175b().max_seq as usize,
        slo: None,
        ..Default::default()
    };
    assert_equivalent(
        "contention",
        ClusterConfig {
            prefix_cache: Some(PrefixCacheConfig::default()),
            contention: ContentionConfig { mode: ContentionMode::Shared, ..Default::default() },
            ..Default::default()
        },
        4,
        traffic_reqs(&tc),
    );
}

#[test]
fn equiv_disaggregated_handoff() {
    // Prefill/decode pools: handoff costing, decode-router placement and
    // injected-sequence admission.
    assert_equivalent(
        "disaggregated",
        ClusterConfig { disaggregate: Some((2, 2)), ..Default::default() },
        4,
        session_workload(24, 6, 512, 12, Seconds::ms(2.0)),
    );
}

#[test]
fn equiv_disaggregated_contended() {
    // Handoff metadata bookings through a per-module ledger.
    assert_equivalent(
        "disaggregated-contended",
        ClusterConfig {
            disaggregate: Some((2, 2)),
            contention: ContentionConfig {
                mode: ContentionMode::PerModule,
                module_interleave: false,
                ..Default::default()
            },
            ..Default::default()
        },
        4,
        session_workload(16, 4, 256, 8, Seconds::ms(5.0)),
    );
}

#[test]
fn equiv_shed_heavy_burst() {
    // Simultaneous burst against a tiny shed watermark: the shed/admit
    // decision depends on router load at each arrival sync — the most
    // order-sensitive path in the cluster.
    let mut reqs = session_workload(24, 4, 256, 8, Seconds::ms(5.0));
    for r in &mut reqs {
        r.arrival = Seconds::ZERO;
    }
    assert_equivalent(
        "shed-burst",
        ClusterConfig { shed_tokens: Some(600), ..Default::default() },
        2,
        reqs,
    );
}

#[test]
fn equiv_rejection_and_affinity() {
    // KV-affinity routing plus inadmissible prompts: rejected requests
    // must unroute identically, leaving identical router state behind.
    let mut reqs = session_workload(20, 4, 256, 8, Seconds::ms(5.0));
    let cap = gpt3_175b().max_seq as usize;
    reqs[3].prompt = vec![1; cap + 1];
    reqs[11].prompt = vec![2; cap * 2];
    assert_equivalent(
        "affinity-rejection",
        ClusterConfig {
            policy: fenghuang::coordinator::Policy::KvAffinity,
            ..Default::default()
        },
        4,
        reqs,
    );
}

fn fault_spec(spec: &str, replicas: usize) -> Option<FaultSchedule> {
    Some(FaultSchedule::parse(spec, replicas).expect("fault spec"))
}

#[test]
fn equiv_fault_crash_midrun() {
    // A replica crash mid-run: evacuation order, router release/mark-dead
    // sequencing and the re-admission routing must be identical — any
    // divergence shifts every later decision.
    assert_equivalent(
        "fault-crash",
        ClusterConfig {
            faults: fault_spec("crash@0.02:r1:repair0.05", 4),
            ..Default::default()
        },
        4,
        session_workload(24, 6, 512, 12, Seconds::ms(2.0)),
    );
}

#[test]
fn equiv_fault_crash_elastic() {
    // Crash + rejoin interleaved with autoscaler ticks: the merged
    // fault/tick loop in the stepping core must replay the event
    // calendar's class order (fault before tick at equal instants).
    let tc = TrafficConfig {
        arrivals: ArrivalConfig {
            pattern: ArrivalPattern::Bursty,
            qps: 12.0,
            ..Default::default()
        },
        mix: WorkloadMix::parse("chat").unwrap(),
        requests: 32,
        seed: 11,
        max_prompt: 4096,
        slo: None,
        ..Default::default()
    };
    assert_equivalent(
        "fault-crash-elastic",
        ClusterConfig {
            autoscale: Some(AutoscaleConfig { target_tokens: 2048, ..Default::default() }),
            faults: fault_spec("crash@0.4:r2:repair0.3", 3),
            ..Default::default()
        },
        3,
        traffic_reqs(&tc),
    );
}

#[test]
fn equiv_fault_module_failure() {
    // TAB module failure under the shared prefix cache: trie-ledger
    // invalidation plus queued-grant revocation, hottest-module
    // selection included.
    let tc = TrafficConfig {
        mix: WorkloadMix::parse("agentic").unwrap(),
        requests: 32,
        seed: 17,
        max_prompt: gpt3_175b().max_seq as usize,
        slo: None,
        ..Default::default()
    };
    assert_equivalent(
        "fault-module",
        ClusterConfig {
            prefix_cache: Some(PrefixCacheConfig::default()),
            faults: fault_spec("module@0.3:hot,module@0.9:m0", 4),
            ..Default::default()
        },
        4,
        traffic_reqs(&tc),
    );
}

#[test]
fn equiv_fault_link_degrade() {
    // Link degradation over the arbitrated fabric: the shrunken window
    // budgets stretch every booking identically in both cores.
    let tc = TrafficConfig {
        mix: WorkloadMix::parse("agentic").unwrap(),
        requests: 32,
        seed: 19,
        max_prompt: gpt3_175b().max_seq as usize,
        slo: None,
        ..Default::default()
    };
    assert_equivalent(
        "fault-degrade",
        ClusterConfig {
            prefix_cache: Some(PrefixCacheConfig::default()),
            contention: ContentionConfig { mode: ContentionMode::Shared, ..Default::default() },
            faults: fault_spec("degrade@0.1:x0.25:d0.5", 4),
            ..Default::default()
        },
        4,
        traffic_reqs(&tc),
    );
}

#[test]
fn equiv_fault_combined() {
    // All three fault classes in one schedule against the full feature
    // stack (prefix cache + per-module arbitration).
    let tc = TrafficConfig {
        mix: WorkloadMix::parse("chat+agentic").unwrap(),
        requests: 40,
        seed: 23,
        max_prompt: gpt3_175b().max_seq as usize,
        slo: None,
        ..Default::default()
    };
    assert_equivalent(
        "fault-combined",
        ClusterConfig {
            prefix_cache: Some(PrefixCacheConfig::default()),
            contention: ContentionConfig {
                mode: ContentionMode::PerModule,
                ..Default::default()
            },
            faults: fault_spec(
                "degrade@0.05:x0.5:d0.4,crash@0.2:r3:repair0.25,module@0.35:hot",
                4,
            ),
            ..Default::default()
        },
        4,
        traffic_reqs(&tc),
    );
}

#[test]
fn equiv_empty_fault_schedule() {
    // An armed-but-empty schedule (knobs only, no events) must still be
    // a passthrough in both cores — and agree with the no-schedule run
    // on every non-fault observable.
    assert_equivalent(
        "fault-empty",
        ClusterConfig {
            faults: Some(FaultSchedule::default()),
            ..Default::default()
        },
        2,
        session_workload(16, 4, 256, 8, Seconds::ms(5.0)),
    );
}

fn tenant_spec(spec: &str) -> TenantsConfig {
    TenantsConfig::parse(spec).expect("tenant spec")
}

#[test]
fn equiv_tenants_wfq_bursty() {
    // Two tenants on two models through the weighted-fair admission
    // arbiter under a binding gate: the DRR deficit walk, admit-tick
    // pump and per-tenant trace counters must replay bit-identically in
    // both cores.
    let mut tenants = tenant_spec("alpha/gpt2/weight=3/mix=chat,beta/gpt2-xl/mix=batch");
    tenants.admit_tokens = Some(2048);
    let base = TrafficConfig {
        arrivals: ArrivalConfig {
            pattern: ArrivalPattern::Bursty,
            qps: 20.0,
            ..Default::default()
        },
        requests: 32,
        seed: 29,
        max_prompt: 1024,
        ..Default::default()
    };
    let reqs = generate_tenant_workload(&tenants, &base).expect("tenant workload");
    assert_equivalent(
        "tenants-wfq-bursty",
        ClusterConfig { tenants: Some(tenants), ..Default::default() },
        2,
        reqs,
    );
}

#[test]
fn equiv_tenants_cold_swap_storm() {
    // Three tenants over two replicas: the homeless tenant keeps forcing
    // cold-start model swaps, whose fabric transfer charge and swap
    // stalls must land on the same requests in the same order.
    let mut tenants = tenant_spec("alpha/gpt2,beta/gpt2-xl,gamma/gpt2/quota=8000");
    tenants.arbitration = TenantArbitration::Fifo;
    tenants.admit_tokens = Some(1024);
    let base = TrafficConfig {
        arrivals: ArrivalConfig { qps: 15.0, ..Default::default() },
        requests: 30,
        seed: 31,
        max_prompt: 1024,
        slo: None,
        ..Default::default()
    };
    let reqs = generate_tenant_workload(&tenants, &base).expect("tenant workload");
    assert_equivalent(
        "tenants-cold-swap",
        ClusterConfig { tenants: Some(tenants), ..Default::default() },
        2,
        reqs,
    );
}

#[test]
fn equiv_tenants_burst_autoscale() {
    // A tenant burst interleaved with autoscaler ticks: the merged
    // admit-tick/scale-tick loop in the stepping core must replay the
    // event calendar's class order, and the autoscaler must see the
    // same queued-but-unadmitted token backlog at every tick.
    let mut tenants = tenant_spec("alpha/gpt2/weight=2/mix=chat,beta/gpt2/mix=batch");
    tenants.admit_tokens = Some(2048);
    let base = TrafficConfig {
        arrivals: ArrivalConfig {
            pattern: ArrivalPattern::Bursty,
            qps: 25.0,
            ..Default::default()
        },
        requests: 36,
        seed: 37,
        max_prompt: 1024,
        slo: None,
        ..Default::default()
    };
    let reqs = generate_tenant_workload(&tenants, &base).expect("tenant workload");
    assert_equivalent(
        "tenants-burst-autoscale",
        ClusterConfig {
            tenants: Some(tenants),
            autoscale: Some(AutoscaleConfig { target_tokens: 2048, ..Default::default() }),
            ..Default::default()
        },
        3,
        reqs,
    );
}

#[test]
fn equiv_telemetry_elastic_kv_pressure() {
    // Telemetry sampling across autoscaler ticks and KV paging: the
    // sampler's tick interleaves with scale ticks in the calendar and
    // the merged stepping loop; every sample gauge, span field and
    // ledger total must replay bit-identically in both cores.
    let tc = TrafficConfig {
        arrivals: ArrivalConfig {
            pattern: ArrivalPattern::Diurnal,
            qps: 10.0,
            diurnal_period: Seconds::new(8.0),
            diurnal_floor: 0.05,
            ..Default::default()
        },
        mix: WorkloadMix::parse("chat").unwrap(),
        requests: 48,
        seed: 7,
        max_prompt: 4096,
        ..Default::default()
    };
    assert_equivalent(
        "telemetry-elastic",
        ClusterConfig {
            autoscale: Some(AutoscaleConfig { target_tokens: 2048, ..Default::default() }),
            kv_budget: Some(Bytes::gb(2.0)),
            telemetry: Some(fenghuang::telemetry::TelemetryConfig {
                interval: Seconds::ms(50.0),
            }),
            ..Default::default()
        },
        4,
        traffic_reqs(&tc),
    );
}

#[test]
fn equiv_telemetry_faulted_prefix() {
    // Telemetry over a faulted run with the shared prefix cache: tick
    // class order against fault events, evacuation-perturbed spans, and
    // the rolling-attainment windows over the completion trace.
    let tc = TrafficConfig {
        mix: WorkloadMix::parse("agentic").unwrap(),
        requests: 32,
        seed: 17,
        max_prompt: gpt3_175b().max_seq as usize,
        ..Default::default()
    };
    assert_equivalent(
        "telemetry-faulted",
        ClusterConfig {
            prefix_cache: Some(PrefixCacheConfig::default()),
            faults: fault_spec("crash@0.3:r1:repair0.2,module@0.6:hot", 4),
            telemetry: Some(fenghuang::telemetry::TelemetryConfig {
                interval: Seconds::ms(50.0),
            }),
            ..Default::default()
        },
        4,
        traffic_reqs(&tc),
    );
}

#[test]
fn equiv_zero_requests() {
    // Degenerate inputs: both cores must agree on the empty run too —
    // including the autoscaled empty run, where the first tick must be
    // dropped rather than tick forever.
    assert_equivalent("empty", ClusterConfig::default(), 2, Vec::new());
    assert_equivalent(
        "empty-elastic",
        ClusterConfig {
            autoscale: Some(AutoscaleConfig::default()),
            ..Default::default()
        },
        2,
        Vec::new(),
    );
}
