//! Property tests for the event-calendar core (DESIGN.md §Event-Core):
//! time-ordering and FIFO invariants of `EventCalendar` under random
//! schedules, past-rejection, drain-to-empty at run end, and arena
//! handle stability across prompt retirement.

use fenghuang::coordinator::{
    AutoscaleConfig, Cluster, ClusterConfig, EventCalendar, EventKind, ReqId, Request,
    RequestArena, session_workload,
};
use fenghuang::models::arch::gpt3_175b;
use fenghuang::traffic::XorShift;
use fenghuang::units::Seconds;

#[test]
fn pop_times_are_nondecreasing_under_random_schedules() {
    // Random pushes interleaved with pops, every new event scheduled at
    // or after the calendar's current instant (as real drivers must):
    // the popped time sequence is nondecreasing, with no event lost.
    for seed in 1..=10u64 {
        let mut rng = XorShift::new(seed);
        let mut cal = EventCalendar::new();
        let mut pushed = 0usize;
        let mut popped = 0usize;
        let mut last = f64::NEG_INFINITY;
        let mut horizon = 0.0f64;
        for _ in 0..500 {
            if rng.next_f64() < 0.6 || cal.is_empty() {
                // Schedule relative to now (never into the past).
                let base = cal.now().map(|t| t.value()).unwrap_or(0.0);
                let t = base + rng.next_f64() * 10.0;
                horizon = horizon.max(t);
                let kind = match rng.range(0, 3) {
                    0 => EventKind::AutoscaleTick,
                    1 => EventKind::Arrival { req: ReqId(pushed as u32) },
                    _ => EventKind::DecodeTick { replica: pushed % 7 },
                };
                assert!(cal.push(Seconds::new(t), kind), "in-future push must be accepted");
                pushed += 1;
            } else {
                let e = cal.pop().expect("non-empty calendar pops");
                assert!(
                    e.time.value() >= last,
                    "seed {seed}: pop at {} after {}",
                    e.time.value(),
                    last
                );
                last = e.time.value();
                popped += 1;
            }
        }
        while let Some(e) = cal.pop() {
            assert!(e.time.value() >= last);
            last = e.time.value();
            popped += 1;
        }
        assert_eq!(pushed, popped, "seed {seed}: every pushed event pops exactly once");
        assert!(cal.is_empty());
        assert_eq!(cal.arrivals_scheduled(), 0);
        assert!(last <= horizon + 1e-12);
    }
}

#[test]
fn equal_timestamps_pop_fifo_within_a_class() {
    // 100 arrivals at the same instant: they pop in push order (the
    // monotone `seq` tie-break), which is what makes sorted workload
    // ingestion replay deterministically.
    let mut cal = EventCalendar::new();
    let t = Seconds::new(2.5);
    for i in 0..100u32 {
        assert!(cal.push(t, EventKind::Arrival { req: ReqId(i) }));
    }
    let mut seqs = Vec::new();
    for want in 0..100u32 {
        let e = cal.pop().unwrap();
        match e.kind {
            EventKind::Arrival { req } => assert_eq!(req, ReqId(want), "FIFO at equal time"),
            other => panic!("unexpected kind {other:?}"),
        }
        seqs.push(e.seq);
    }
    assert!(seqs.windows(2).all(|w| w[0] < w[1]), "seq is strictly monotone");
}

#[test]
fn class_orders_same_instant_events_like_the_stepping_loop() {
    // At one timestamp: autoscale tick first, then replica-local
    // completions, then arrivals — regardless of push order.
    let mut cal = EventCalendar::new();
    let t = Seconds::new(1.0);
    assert!(cal.push(t, EventKind::Arrival { req: ReqId(0) }));
    assert!(cal.push(t, EventKind::DecodeTick { replica: 3 }));
    assert!(cal.push(t, EventKind::PrefillDone { replica: 1 }));
    assert!(cal.push(t, EventKind::AutoscaleTick));
    assert!(cal.push(t, EventKind::MigrationDone { replica: 0 }));
    assert!(cal.push(t, EventKind::HandoffDone { replica: 2 }));
    let order: Vec<EventKind> = std::iter::from_fn(|| cal.pop()).map(|e| e.kind).collect();
    assert_eq!(
        order,
        vec![
            EventKind::AutoscaleTick,
            EventKind::HandoffDone { replica: 2 },
            EventKind::MigrationDone { replica: 0 },
            EventKind::PrefillDone { replica: 1 },
            EventKind::DecodeTick { replica: 3 },
            EventKind::Arrival { req: ReqId(0) },
        ]
    );
}

#[test]
fn no_event_can_be_scheduled_in_the_past() {
    let mut cal = EventCalendar::new();
    assert!(cal.push(Seconds::new(5.0), EventKind::AutoscaleTick));
    assert!(cal.push(Seconds::new(1.0), EventKind::AutoscaleTick));
    cal.pop(); // now = 1.0
    cal.pop(); // now = 5.0
    assert!(!cal.push(Seconds::new(4.999), EventKind::AutoscaleTick), "past push rejected");
    assert!(cal.is_empty(), "rejected push schedules nothing");
    assert!(cal.push(Seconds::new(5.0), EventKind::AutoscaleTick), "push at now is legal");
    assert!(cal.push(Seconds::new(5.1), EventKind::Arrival { req: ReqId(0) }));
    assert_eq!(cal.len(), 2);
    // A rejected push must not bump the arrival gauge either.
    assert!(!cal.push(Seconds::new(0.0), EventKind::Arrival { req: ReqId(1) }));
    assert_eq!(cal.arrivals_scheduled(), 1);
}

#[test]
fn calendar_drains_empty_at_run_end() {
    // Replay the driver's schedule shape: N arrivals plus a
    // self-rescheduling tick that stops once arrivals and work run out.
    let mut cal = EventCalendar::new();
    for i in 0..40u32 {
        assert!(cal.push(Seconds::new(i as f64 * 0.25), EventKind::Arrival { req: ReqId(i) }));
    }
    let interval = Seconds::new(1.0);
    assert!(cal.push(interval, EventKind::AutoscaleTick));
    let mut pending = 0usize; // work the "fleet" still holds
    let mut next_scale = interval;
    while let Some(e) = cal.pop() {
        match e.kind {
            EventKind::Arrival { .. } => pending += 2, // two steps of work each
            EventKind::AutoscaleTick => {
                if cal.arrivals_scheduled() == 0 && pending == 0 {
                    continue; // dropped: the calendar must now drain
                }
                pending = pending.saturating_sub(3); // fleet drains between ticks
                next_scale += interval;
                assert!(cal.push(next_scale, EventKind::AutoscaleTick));
            }
            other => panic!("driver never schedules {other:?}"),
        }
    }
    assert!(cal.is_empty(), "run end leaves no orphaned events");
    assert_eq!(cal.arrivals_scheduled(), 0);

    // And end-to-end: an autoscaled event-core run terminates with every
    // request accounted for — the loop exits only by draining the
    // calendar, so completion *is* the drain proof.
    let reqs = session_workload(32, 4, 256, 8, Seconds::ms(5.0));
    let cfg = ClusterConfig {
        autoscale: Some(AutoscaleConfig { target_tokens: 1024, ..Default::default() }),
        ..Default::default()
    };
    let mut c = Cluster::fh4(4, &gpt3_175b(), cfg).unwrap();
    let r = c.run(reqs).unwrap();
    assert_eq!(r.fleet.completed + r.fleet.rejected + r.fleet.shed, 32);
}

#[test]
fn arena_handles_never_dangle_across_retirement() {
    let mut arena = RequestArena::new();
    let mut rng = XorShift::new(42);
    let mut ids: Vec<ReqId> = Vec::new();
    let mut expect: Vec<(u64, usize, usize)> = Vec::new();
    for i in 0..500u64 {
        let plen = 1 + rng.range(1, 300) as usize;
        let gen = 1 + rng.range(0, 40) as usize;
        ids.push(arena.alloc(Request {
            id: i,
            prompt: vec![(i % 500) as i32 + 1; plen],
            max_new_tokens: gen,
            arrival: Seconds::ms(i as f64),
            ..Default::default()
        }));
        expect.push((i, plen, gen));
        // Retire a random earlier request mid-stream, like the driver
        // does after each admission.
        if i % 3 == 0 {
            let victim = ids[rng.range(0, ids.len() as u64 - 1) as usize];
            arena.retire_prompt(victim);
            assert!(arena.is_retired(victim));
        }
    }
    // Retire everything (idempotent for the already-retired) and check
    // every handle still resolves to its frozen metadata.
    for &id in &ids {
        arena.retire_prompt(id);
    }
    for (id, &(orig, plen, gen)) in ids.iter().zip(&expect) {
        let e = arena.get(*id);
        assert_eq!(e.id, orig);
        assert_eq!(e.prompt_len, plen);
        assert_eq!(e.max_new_tokens, gen);
        assert_eq!(e.work_tokens(), (plen + gen) as u64);
        assert!(e.prompt().is_empty(), "retired prompts hold no tokens");
        assert!(e.prefill_len() >= 1);
        assert_eq!(e.arrival, Seconds::ms(orig as f64));
    }
    assert_eq!(arena.len(), 500);
}
