//! Statistical tests for the open-loop arrival processes
//! (DESIGN.md §Traffic): the generators must not just run — their
//! *distributions* must match what they claim to model. Every test is
//! seeded, so these are deterministic regressions, not flaky monte-carlo
//! checks; tolerances are sized at many standard errors so only a real
//! distribution change can trip them.

use fenghuang::traffic::{arrival_times, ArrivalConfig, ArrivalPattern, XorShift};
use fenghuang::units::Seconds;

fn times(cfg: &ArrivalConfig, n: usize, seed: u64) -> Vec<Seconds> {
    arrival_times(cfg, n, &mut XorShift::new(seed)).expect("arrivals")
}

/// Arrival counts per unit-length window over the span covered by `a`.
fn window_counts(a: &[Seconds], window_s: f64) -> Vec<u64> {
    let span = a.last().map(|t| t.value()).unwrap_or(0.0);
    let n = (span / window_s).floor() as usize;
    let mut counts = vec![0u64; n.max(1)];
    for t in a {
        let w = (t.value() / window_s) as usize;
        if w < counts.len() {
            counts[w] += 1;
        }
    }
    counts
}

/// Variance-to-mean ratio (index of dispersion) of window counts: ≈ 1
/// for a Poisson process, ≫ 1 for a bursty (overdispersed) one.
fn vmr(counts: &[u64]) -> f64 {
    let n = counts.len() as f64;
    let mean = counts.iter().sum::<u64>() as f64 / n;
    if mean == 0.0 {
        return 0.0;
    }
    let var = counts.iter().map(|&c| (c as f64 - mean).powi(2)).sum::<f64>() / n;
    var / mean
}

#[test]
fn poisson_sample_mean_matches_target_qps() {
    // Mean inter-arrival gap of a Poisson process at rate λ is 1/λ; with
    // n = 5000 the standard error of the sample mean is (1/λ)/√n ≈ 0.07%
    // of the mean, so a ±10% band is ~70 standard errors — it can only
    // fail if the generator's rate is actually wrong.
    for (seed, qps) in [(3u64, 20.0f64), (11, 5.0), (29, 80.0)] {
        let cfg = ArrivalConfig { pattern: ArrivalPattern::Poisson, qps, ..Default::default() };
        let n = 5000;
        let a = times(&cfg, n, seed);
        assert_eq!(a.len(), n);
        let span = a.last().unwrap().value();
        let rate = n as f64 / span;
        assert!(
            (rate - qps).abs() < 0.1 * qps,
            "seed {seed}: empirical rate {rate:.3} vs target {qps}"
        );
        // Exponential gaps: the coefficient of variation of the gap
        // distribution is 1; sample CV must land near it.
        let gaps: Vec<f64> = std::iter::once(a[0].value())
            .chain(a.windows(2).map(|w| (w[1] - w[0]).value()))
            .collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|g| (g - mean).powi(2)).sum::<f64>() / gaps.len() as f64;
        let cv = var.sqrt() / mean;
        assert!((cv - 1.0).abs() < 0.15, "seed {seed}: gap CV {cv:.3} far from exponential");
    }
}

#[test]
fn bursty_counts_are_overdispersed_relative_to_poisson() {
    // The MMPP on-off process clumps arrivals into on-state bursts: its
    // window-count variance-to-mean ratio must clearly exceed the
    // Poisson index of dispersion (≈ 1).
    let qps = 40.0;
    let bursty = ArrivalConfig {
        pattern: ArrivalPattern::Bursty,
        qps,
        burst_on: Seconds::new(1.0),
        burst_off: Seconds::new(3.0),
        burst_idle_frac: 0.05,
        ..Default::default()
    };
    let poisson = ArrivalConfig { pattern: ArrivalPattern::Poisson, qps, ..Default::default() };
    let vb = vmr(&window_counts(&times(&bursty, 3000, 5), 1.0));
    let vp = vmr(&window_counts(&times(&poisson, 3000, 5), 1.0));
    assert!(vp < 2.0, "Poisson dispersion {vp:.2} should sit near 1");
    assert!(vb > 2.0, "bursty dispersion {vb:.2} must be overdispersed");
    assert!(
        vb > 2.0 * vp,
        "burstiness must dominate: bursty VMR {vb:.2} vs poisson {vp:.2}"
    );
}

#[test]
fn diurnal_rate_modulation_repeats_with_the_period() {
    // λ(t) troughs at t ≡ 0 (mod P) and peaks at t ≡ P/2: the peak-window
    // count must dwarf the trough-window count in *both* of the first two
    // periods — same phase, one period apart — which pins the period,
    // not just "some modulation".
    let period = 20.0;
    let cfg = ArrivalConfig {
        pattern: ArrivalPattern::Diurnal,
        qps: 50.0,
        diurnal_period: Seconds::new(period),
        diurnal_floor: 0.05,
        ..Default::default()
    };
    let a = times(&cfg, 1600, 9);
    let span = a.last().unwrap().value();
    assert!(span > 2.0 * period, "sample must cover two full periods, got {span:.1}s");
    let count_in = |lo: f64, hi: f64| {
        a.iter().filter(|t| t.value() >= lo && t.value() < hi).count() as f64
    };
    for cycle in 0..2 {
        let base = cycle as f64 * period;
        let trough = count_in(base, base + 0.1 * period);
        let peak = count_in(base + 0.45 * period, base + 0.55 * period);
        assert!(
            peak > 3.0 * trough.max(1.0),
            "cycle {cycle}: peak window {peak} must dwarf trough window {trough}"
        );
    }
    // Same-phase windows across consecutive periods carry similar rates:
    // the second peak is within a factor of three of the first (loose —
    // both are ≈ P·qps/10 in expectation).
    let p1 = count_in(0.45 * period, 0.55 * period);
    let p2 = count_in(1.45 * period, 1.55 * period);
    assert!(
        p2 > p1 / 3.0 && p2 < p1 * 3.0,
        "periodicity broken: peak counts {p1} vs {p2} one period apart"
    );
}

#[test]
fn same_seed_regenerates_byte_identical_streams() {
    // Bit-for-bit regeneration is the contract the golden tests and the
    // `--seed` CLI flag stand on — assert exact equality, not tolerance.
    for pattern in ArrivalPattern::synthetic() {
        let cfg = ArrivalConfig { pattern, qps: 17.0, ..Default::default() };
        let a = times(&cfg, 500, 123);
        let b = times(&cfg, 500, 123);
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert!(
                x.value().to_bits() == y.value().to_bits(),
                "{} diverged at arrival {i}: {x:?} vs {y:?}",
                pattern.name()
            );
        }
        let c = times(&cfg, 500, 124);
        assert!(
            a.iter().zip(&c).any(|(x, y)| x != y),
            "{} must vary with the seed",
            pattern.name()
        );
    }
    // The full generator composes arrivals + mix draws; it must be
    // byte-identical too (prompt token streams included).
    use fenghuang::traffic::{generate, TrafficConfig, WorkloadMix};
    let tc = TrafficConfig {
        mix: WorkloadMix::parse("chat+rag+agentic+batch").expect("mix"),
        requests: 200,
        seed: 31,
        ..Default::default()
    };
    let a = generate(&tc).expect("workload");
    let b = generate(&tc).expect("workload");
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.prompt, y.prompt);
        assert_eq!(x.max_new_tokens, y.max_new_tokens);
        assert!(x.arrival.value().to_bits() == y.arrival.value().to_bits());
    }
}
