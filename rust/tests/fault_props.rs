//! Property tests for the fault-injection subsystem (DESIGN.md §Faults).
//!
//! Invariants pinned:
//! * **passthrough** — an absent schedule and an armed-but-empty one
//!   produce bit-identical fleet metrics on both cluster cores: the
//!   fault machinery must cost a healthy run nothing, not even an f64
//!   rounding step;
//! * **conservation** — every submitted request is completed, rejected
//!   or shed exactly once, crashes and re-admissions included;
//! * **blast radius** — `PrefixCache::fail_module` invalidates exactly
//!   the bytes the per-module ledger attributed to the dead module, for
//!   both striped and hashed placement;
//! * **determinism** — a seeded random schedule materialises the same
//!   timeline every parse, and a faulted run replays bit-identically;
//! * **golden scenario** — a fixed three-fault schedule reports exactly
//!   the per-class counts and recovery shape it was constructed to.

use fenghuang::config::fh4_15xm;
use fenghuang::coordinator::{
    session_workload, Cluster, ClusterConfig, ClusterReport, PoolPlacement, PrefixCache,
    PrefixCacheConfig,
};
use fenghuang::fabric::contention::{ContentionConfig, ContentionMode};
use fenghuang::faults::{FaultKind, FaultSchedule};
use fenghuang::models::arch::gpt3_175b;
use fenghuang::models::memory;
use fenghuang::units::{Bandwidth, Bytes, Seconds};

fn run_cluster(cfg: ClusterConfig, replicas: usize, n: usize) -> ClusterReport {
    let mut cluster = Cluster::fh4(replicas, &gpt3_175b(), cfg).expect("cluster");
    cluster
        .run(session_workload(n, 6, 512, 12, Seconds::ms(2.0)))
        .expect("run")
}

/// The non-fault observables a passthrough must hold bit-identical.
fn fingerprint(r: &ClusterReport) -> Vec<u64> {
    vec![
        (r.fleet.completed as f64).to_bits(),
        (r.fleet.rejected as f64).to_bits(),
        (r.fleet.shed as f64).to_bits(),
        (r.fleet.tokens_generated as f64).to_bits(),
        r.fleet.clock.value().to_bits(),
        r.fleet.busy.value().to_bits(),
        r.fleet.prefix_fetch.value().to_bits(),
        r.fleet.fabric_wait.value().to_bits(),
        r.fleet.ttft.mean_ms().to_bits(),
        r.fleet.ttft.percentile_ms(99.0).to_bits(),
        r.fleet.tpot.mean_ms().to_bits(),
        r.fleet.e2e.percentile_ms(95.0).to_bits(),
        r.imbalance.to_bits(),
        r.replica_seconds.to_bits(),
        r.kv_spilled_peak.value().to_bits(),
    ]
}

#[test]
fn empty_schedule_is_bit_identical_passthrough() {
    let featureful = || ClusterConfig {
        prefix_cache: Some(PrefixCacheConfig::default()),
        contention: ContentionConfig { mode: ContentionMode::Shared, ..Default::default() },
        ..Default::default()
    };
    let absent = run_cluster(featureful(), 4, 24);
    let empty = run_cluster(
        ClusterConfig { faults: Some(FaultSchedule::default()), ..featureful() },
        4,
        24,
    );
    assert_eq!(fingerprint(&absent), fingerprint(&empty), "event core passthrough");
    assert!(absent.faults.is_none(), "no schedule → no fault report");
    let fr = empty.faults.as_ref().expect("armed schedule reports");
    assert_eq!(fr.crashes + fr.module_failures + fr.link_degrades, 0);
    assert!(fr.recovered, "a fault-free run is trivially recovered");

    // Stepping core: same passthrough law.
    let mut a = Cluster::fh4(4, &gpt3_175b(), featureful()).expect("cluster");
    let sa = a
        .run_stepping(session_workload(24, 6, 512, 12, Seconds::ms(2.0)))
        .expect("stepping");
    let mut b = Cluster::fh4(
        4,
        &gpt3_175b(),
        ClusterConfig { faults: Some(FaultSchedule::default()), ..featureful() },
    )
    .expect("cluster");
    let sb = b
        .run_stepping(session_workload(24, 6, 512, 12, Seconds::ms(2.0)))
        .expect("stepping");
    assert_eq!(fingerprint(&sa), fingerprint(&sb), "stepping core passthrough");
}

#[test]
fn conservation_holds_under_crash_faults() {
    let n = 32;
    let cfg = ClusterConfig {
        faults: Some(
            FaultSchedule::parse("crash@0.01:r1:repair0.05,crash@0.03:r2:repair0.1", 4)
                .expect("spec"),
        ),
        ..Default::default()
    };
    let r = run_cluster(cfg, 4, n);
    let fr = r.faults.as_ref().expect("fault report");
    assert_eq!(fr.crashes, 2);
    assert_eq!(fr.rejoins, 2, "every crash derives its rejoin");
    assert!(fr.requests_requeued > 0, "mid-run crashes must evacuate work");
    assert_eq!(
        r.fleet.completed + r.fleet.rejected + r.fleet.shed,
        n as u64,
        "every request is completed, rejected or shed exactly once \
         (requeued {} / lost {} tokens)",
        fr.requests_requeued,
        fr.tokens_lost,
    );
}

#[test]
fn module_blast_radius_matches_the_ledger() {
    let sys = fh4_15xm(Bandwidth::tbps(4.8));
    let model = gpt3_175b();
    for placement in [PoolPlacement::Striped, PoolPlacement::Hashed] {
        let mut pc = PrefixCache::new(
            PrefixCacheConfig { modules: 8, placement, max_tokens: 64, ..Default::default() },
            &sys,
            &model,
        )
        .expect("cache");
        // 16 chains with distinct first tokens — chain-granular homing
        // spreads (striped) or collides (hashed) across the 8 modules.
        for s in 0..16i32 {
            let prompt: Vec<i32> = (0..32).map(|i| s * 64 + i + 1).collect();
            assert!(pc.insert(&prompt, 0) > 0, "fresh chain must insert");
        }
        let per_module: Vec<Bytes> = (0..8).map(|m| pc.module_bytes(m)).collect();
        let total: f64 = per_module.iter().map(|b| b.value()).sum();
        let bpt = memory::kv_cache_bytes(&model, 1, 1);
        assert!(
            (total - bpt.value() * 16.0 * 32.0).abs() < 1e-3,
            "ledger must account every inserted extent ({placement:?})"
        );
        let hot = pc.hottest_module();
        assert!(
            per_module.iter().all(|b| b.value() <= per_module[hot].value()),
            "hottest_module must name the max ({placement:?})"
        );
        // Kill every module in turn: each blast radius is exactly what
        // the ledger said, and the pool ends empty.
        for m in 0..8 {
            let before = pc.module_bytes(m);
            let (bytes, extents) = pc.fail_module(m);
            assert_eq!(bytes.value(), before.value(), "blast == ledger ({placement:?}, m{m})");
            assert_eq!(pc.module_bytes(m).value(), 0.0);
            if before.value() > 0.0 {
                assert!(extents > 0);
            }
        }
        assert!((0..8).all(|m| pc.module_bytes(m).value() == 0.0));
        // A killed prefix is a miss, then re-inserts cold.
        let prompt: Vec<i32> = (0..32).map(|i| i + 1).collect();
        assert_eq!(pc.lookup(&prompt).tokens, 0, "dead extents must not hit");
        assert!(pc.insert(&prompt, 0) > 0, "re-publication after failure");
    }
}

#[test]
fn hashed_placement_concentrates_at_least_as_much_as_striped() {
    let sys = fh4_15xm(Bandwidth::tbps(4.8));
    let model = gpt3_175b();
    let hot_bytes = |placement: PoolPlacement| -> f64 {
        let mut pc = PrefixCache::new(
            PrefixCacheConfig { modules: 8, placement, max_tokens: 64, ..Default::default() },
            &sys,
            &model,
        )
        .expect("cache");
        for s in 0..16i32 {
            let prompt: Vec<i32> = (0..32).map(|i| s * 64 + i + 1).collect();
            pc.insert(&prompt, 0);
        }
        pc.module_bytes(pc.hottest_module()).value()
    };
    let striped = hot_bytes(PoolPlacement::Striped);
    let hashed = hot_bytes(PoolPlacement::Hashed);
    assert!(striped > 0.0 && hashed > 0.0);
    // Round-robin chain placement is the uniform lower bound; hashing 16
    // chains into 8 modules collides by pigeonhole, so its hottest
    // module carries at least the striped share.
    assert!(
        hashed >= striped - 1e-9,
        "hashed hottest module {hashed:.1} B below striped {striped:.1} B"
    );
}

#[test]
fn random_schedules_and_faulted_runs_are_deterministic() {
    let spec = "random:seed=9:horizon=0.5:crash=4.0:module=2.0:degrade=2.0";
    let a = FaultSchedule::parse(spec, 4).expect("spec");
    let b = FaultSchedule::parse(spec, 4).expect("spec");
    assert_eq!(a, b, "same seed → same materialised timeline");
    assert!(!a.is_empty(), "rates × horizon chosen to land events");
    // Crash targets must stay inside the fleet.
    for e in &a.events {
        if let FaultKind::ReplicaCrash { replica, .. } = e.kind {
            assert!(replica < 4);
        }
    }
    // A faulted cluster run replays bit-identically (no hidden clocks,
    // no ambient randomness in the fault paths).
    let cfg = || ClusterConfig {
        prefix_cache: Some(PrefixCacheConfig::default()),
        faults: Some(FaultSchedule::parse("crash@0.02:r1:repair0.05,module@0.04:hot", 4).unwrap()),
        ..Default::default()
    };
    let r1 = run_cluster(cfg(), 4, 24);
    let r2 = run_cluster(cfg(), 4, 24);
    assert_eq!(fingerprint(&r1), fingerprint(&r2), "faulted runs must replay exactly");
    let (f1, f2) = (r1.faults.unwrap(), r2.faults.unwrap());
    assert_eq!(f1.requests_requeued, f2.requests_requeued);
    assert_eq!(f1.tokens_lost, f2.tokens_lost);
    assert_eq!(f1.slo_dip.to_bits(), f2.slo_dip.to_bits());
}

#[test]
fn golden_three_fault_scenario_reports_its_shape() {
    let cfg = ClusterConfig {
        prefix_cache: Some(PrefixCacheConfig::default()),
        contention: ContentionConfig { mode: ContentionMode::Shared, ..Default::default() },
        faults: Some(
            FaultSchedule::parse(
                "degrade@0.005:x0.5:d0.1,crash@0.02:r1:repair0.08,module@0.03:hot,window=0.02",
                4,
            )
            .expect("spec"),
        ),
        ..Default::default()
    };
    let r = run_cluster(cfg, 4, 32);
    let fr = r.faults.as_ref().expect("fault report");
    assert_eq!(fr.crashes, 1);
    assert_eq!(fr.rejoins, 1);
    assert_eq!(fr.module_failures, 1);
    assert_eq!(fr.link_degrades, 1);
    assert_eq!(fr.first_fault.map(|s| s.value()), Some(0.005));
    assert!(fr.window.value() > 0.0);
    assert!(
        fr.bytes_invalidated.value() > 0.0,
        "a hot-module kill under agentic-style sessions must invalidate bytes"
    );
    assert!(fr.baseline_attainment >= 0.0 && fr.baseline_attainment <= 1.0);
    assert!(fr.dip_attainment >= 0.0 && fr.dip_attainment <= 1.0);
    assert!(fr.slo_dip >= 0.0);
    // The summary line carries the per-class counts for the CLI.
    let line = fr.summary_line();
    assert!(line.contains("1 crash"), "{line}");
    assert!(line.contains("1 module"), "{line}");
    assert!(line.contains("1 degrade"), "{line}");
    // All work still conserved.
    assert_eq!(r.fleet.completed + r.fleet.rejected + r.fleet.shed, 32);
}
