//! Documentation-reference checker: every section citation of DESIGN.md
//! or EXPERIMENTS.md in the source tree must resolve to a real heading
//! of that document, so the docs layer can't silently rot. (The offline
//! build has no regex crate; matching is plain string scanning.)

use std::fs;
use std::path::{Path, PathBuf};

fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

/// Collect every .rs/.py file under the code roots.
fn source_files() -> Vec<PathBuf> {
    let mut out = Vec::new();
    for root in ["rust", "benches", "examples", "python"] {
        walk(&repo_root().join(root), &mut out);
    }
    assert!(out.len() > 30, "source walk looks broken: {} files", out.len());
    out
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else { return };
    for e in entries.flatten() {
        let p = e.path();
        if p.is_dir() {
            walk(&p, out);
        } else if matches!(p.extension().and_then(|x| x.to_str()), Some("rs" | "py")) {
            out.push(p);
        }
    }
}

/// Section token at the head of `tail` (text right after a '§'):
/// returns (raw length consumed, trimmed token), or None.
fn token_at(tail: &str) -> Option<(usize, String)> {
    let raw: String = tail
        .chars()
        .take_while(|c| c.is_ascii_alphanumeric() || *c == '.' || *c == '-')
        .collect();
    let tok = raw.trim_end_matches(['.', '-']).to_string();
    if tok.is_empty() {
        None
    } else {
        Some((raw.len(), tok))
    }
}

/// Sections of `doc` cited on `line`, via the two adjacency patterns the
/// tree uses: `DOC §TOK` and `§TOK of DOC`. Bare paper references like
/// "(§3.3.1)" never bind to a doc file.
fn cited_sections(line: &str, doc: &str) -> Vec<String> {
    let mut out = Vec::new();
    let pat = format!("{doc} §");
    let mut start = 0;
    while let Some(i) = line[start..].find(&pat) {
        let at = start + i + pat.len();
        if let Some((_, tok)) = token_at(&line[at..]) {
            out.push(tok);
        }
        start = at;
    }
    for (i, _) in line.match_indices('§') {
        let tail = &line[i + '§'.len_utf8()..];
        if let Some((raw, tok)) = token_at(tail) {
            let rest = &tail[raw..];
            if rest.starts_with(&format!(" of {doc}")) {
                out.push(tok);
            }
        }
    }
    out
}

/// §-markers carried by the markdown headings of `doc`: the *first*
/// §-token per heading line only, so incidental paper references in a
/// heading ("## §Speedup — §3.3.3 …") don't become citable targets.
fn headings(doc: &str) -> Vec<String> {
    let mut out = Vec::new();
    for line in doc.lines().filter(|l| l.starts_with('#')) {
        if let Some(i) = line.find('§') {
            if let Some((_, tok)) = token_at(&line[i + '§'.len_utf8()..]) {
                out.push(tok);
            }
        }
    }
    out
}

#[test]
fn design_and_experiments_docs_exist() {
    for doc in ["DESIGN.md", "EXPERIMENTS.md", "README.md"] {
        let p = repo_root().join(doc);
        assert!(p.exists(), "{doc} is missing (cited throughout the source tree)");
        let text = fs::read_to_string(&p).unwrap();
        assert!(text.len() > 500, "{doc} is a stub ({} bytes)", text.len());
    }
}

#[test]
fn every_inline_doc_section_reference_resolves() {
    let mut missing = Vec::new();
    let docs: Vec<(&str, Vec<String>)> = ["DESIGN.md", "EXPERIMENTS.md"]
        .into_iter()
        .map(|name| {
            let text = fs::read_to_string(repo_root().join(name)).unwrap_or_default();
            let secs = headings(&text);
            assert!(!secs.is_empty(), "{name} has no §-marked headings");
            (name, secs)
        })
        .collect();
    let files = source_files();
    let mut checked = 0usize;
    for file in &files {
        let Ok(text) = fs::read_to_string(file) else { continue };
        for (lineno, line) in text.lines().enumerate() {
            for (doc_name, secs) in &docs {
                if !line.contains(doc_name) {
                    continue;
                }
                for sec in cited_sections(line, doc_name) {
                    checked += 1;
                    // A §N citation accepts any §N or §N.x heading.
                    let ok = secs
                        .iter()
                        .any(|h| h == &sec || h.starts_with(&format!("{sec}.")));
                    if !ok {
                        missing.push(format!(
                            "{}:{}: §{} not found in {}",
                            file.strip_prefix(repo_root()).unwrap_or(file).display(),
                            lineno + 1,
                            sec,
                            doc_name,
                        ));
                    }
                }
            }
        }
    }
    assert!(checked >= 8, "doc-reference scan found only {checked} citations — scanner broken?");
    assert!(missing.is_empty(), "dangling doc references:\n{}", missing.join("\n"));
}

#[test]
fn citation_parser_handles_the_tree_idioms() {
    assert_eq!(
        cited_sections("traces (§4.1.3, and DESIGN.md §1 substitution table).", "DESIGN.md"),
        vec!["1"],
        "paper §refs on the same line must not bind to the doc"
    );
    assert_eq!(
        cited_sections("(for DESIGN.md §Perf: VMEM)", "DESIGN.md"),
        vec!["Perf"]
    );
    assert_eq!(
        cited_sections("(DESIGN.md §Hardware-Adaptation): x", "DESIGN.md"),
        vec!["Hardware-Adaptation"]
    );
    assert_eq!(
        cited_sections("microbenchmarks (§Perf of EXPERIMENTS.md).", "EXPERIMENTS.md"),
        vec!["Perf"]
    );
    assert_eq!(
        cited_sections("see EXPERIMENTS.md §Perf.)", "EXPERIMENTS.md"),
        vec!["Perf"]
    );
    assert!(cited_sections("plain (§3.3.1) reference", "DESIGN.md").is_empty());
    assert_eq!(headings("# Title\n## §5 Knobs\ntext §9\n### §4.1 Figures"), vec!["5", "4.1"]);
}
