#!/usr/bin/env bash
# Emit the machine-readable bench artifacts (BENCH_*.json at the repo
# root) that seed the perf trajectory (EXPERIMENTS.md §Capacity-Sweep,
# §Serve-Scale, §Traffic-Sweep, §Fault-Sweep).
#
#   scripts/bench_json.sh            # paging_sweep + serve_scale + traffic_sweep + prefix_cache + fabric_contention + fault_sweep + tenant_sweep + telemetry_overhead + perf_hotpath
#   scripts/bench_json.sh paging     # just the capacity sweep
#   scripts/bench_json.sh serve      # just the cluster sweep
#   scripts/bench_json.sh traffic    # just the open-loop traffic sweep
#   scripts/bench_json.sh prefix     # just the shared prefix-cache sweep
#   scripts/bench_json.sh contention # just the shared-fabric contention sweep
#   scripts/bench_json.sh faults     # just the fault-injection sweep
#   scripts/bench_json.sh tenants    # just the multi-tenant isolation sweep
#   scripts/bench_json.sh telemetry  # just the telemetry overhead gate
#   scripts/bench_json.sh perf       # just the hot-path micro-benchmarks
set -euo pipefail
cd "$(dirname "$0")/.."

want="${1:-all}"

case "$want" in
    all|paging|serve|traffic|prefix|contention|faults|tenants|telemetry|perf) ;;
    *)
        echo "error: unknown target '$want' (expected: all, paging, serve, traffic, prefix, contention, faults, tenants, telemetry or perf)" >&2
        exit 2
        ;;
esac
if [[ $# -gt 1 ]]; then
    echo "error: unexpected extra arguments: ${*:2} (one target at most)" >&2
    exit 2
fi

if [[ "$want" == "all" || "$want" == "paging" ]]; then
    cargo bench --bench paging_sweep -- --json
fi
if [[ "$want" == "all" || "$want" == "serve" ]]; then
    cargo bench --bench serve_scale -- --json
fi
if [[ "$want" == "all" || "$want" == "traffic" ]]; then
    cargo bench --bench traffic_sweep -- --json
fi
if [[ "$want" == "all" || "$want" == "prefix" ]]; then
    cargo bench --bench prefix_cache -- --json
fi
if [[ "$want" == "all" || "$want" == "contention" ]]; then
    cargo bench --bench fabric_contention -- --json
fi
if [[ "$want" == "all" || "$want" == "faults" ]]; then
    cargo bench --bench fault_sweep -- --json
fi
if [[ "$want" == "all" || "$want" == "tenants" ]]; then
    cargo bench --bench tenant_sweep -- --json
fi
if [[ "$want" == "all" || "$want" == "telemetry" ]]; then
    cargo bench --bench telemetry_overhead -- --json
fi
if [[ "$want" == "all" || "$want" == "perf" ]]; then
    cargo bench --bench perf_hotpath -- --json
fi

echo
echo "artifacts:"
ls -l BENCH_*.json
