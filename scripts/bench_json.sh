#!/usr/bin/env bash
# Emit the machine-readable bench artifacts (BENCH_*.json at the repo
# root) that seed the perf trajectory (EXPERIMENTS.md §Capacity-Sweep,
# §Serve-Scale).
#
#   scripts/bench_json.sh            # paging_sweep + serve_scale
#   scripts/bench_json.sh paging     # just the capacity sweep
#   scripts/bench_json.sh serve      # just the cluster sweep
set -euo pipefail
cd "$(dirname "$0")/.."

want="${1:-all}"

if [[ "$want" == "all" || "$want" == "paging" ]]; then
    cargo bench --bench paging_sweep -- --json
fi
if [[ "$want" == "all" || "$want" == "serve" ]]; then
    cargo bench --bench serve_scale -- --json
fi

echo
echo "artifacts:"
ls -l BENCH_*.json
