#!/usr/bin/env bash
# Tier-1 CI gate, offline-safe (the crate is zero-dependency, so no
# network is needed beyond a Rust toolchain):
#
#   1. release build + full test suite (the ROADMAP tier-1 contract);
#   2. a --json --smoke run of every bench target, so the JSON emitters
#      and every sweep's code path stay green without burning CI minutes
#      on the full grids (heavy benches shrink under --smoke; cheap
#      analytic benches ignore it).
#
#   scripts/ci.sh            # everything
#   scripts/ci.sh build      # build + tests only
set -euo pipefail
cd "$(dirname "$0")/.."

want="${1:-all}"
case "$want" in
    all|build) ;;
    *)
        echo "error: unknown target '$want' (expected: all or build)" >&2
        exit 2
        ;;
esac
if [[ $# -gt 1 ]]; then
    echo "error: unexpected extra arguments: ${*:2} (one target at most)" >&2
    exit 2
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

# Test-count floor: future PRs must not silently drop tests. The count
# is the number of #[test] annotations in the tree (toolchain-free, so
# it also runs in environments without cargo); the committed floor moves
# only via scripts/update_test_floor.sh.
echo "== test-count floor =="
tests_now=$(grep -rE '^\s*#\[test\]' rust benches examples --include='*.rs' | wc -l | tr -d ' ')
floor_file=scripts/test_floor.txt
if [[ -f "$floor_file" ]]; then
    floor=$(tr -d '[:space:]' < "$floor_file")
    echo "tests: $tests_now (floor: $floor)"
    if (( tests_now < floor )); then
        echo "error: test count dropped below the committed floor" >&2
        echo "       ($tests_now < $floor — restore the tests, or lower the floor" >&2
        echo "       deliberately via scripts/update_test_floor.sh with justification)" >&2
        exit 1
    fi
    if (( tests_now > floor )); then
        echo "notice: test count grew to $tests_now — bump the floor with scripts/update_test_floor.sh"
    fi
else
    echo "notice: $floor_file missing — seed it with scripts/update_test_floor.sh and commit it"
fi

# The golden regression floor only binds across checkouts once the
# snapshot the first test run generates is committed (rust/tests/golden.rs).
if [[ -f rust/tests/golden_values.txt ]] && command -v git >/dev/null \
    && ! git ls-files --error-unmatch rust/tests/golden_values.txt >/dev/null 2>&1; then
    echo "notice: rust/tests/golden_values.txt was generated but is NOT committed —"
    echo "        commit it so golden.rs compares instead of re-seeding every checkout."
fi

if [[ "$want" == "build" ]]; then
    exit 0
fi

BENCHES=(
    ablations
    collective_speedup
    fabric_contention
    fault_sweep
    fig1_trends
    fig2_hw_trends
    fig2_model_trends
    fig4_workloads
    paging_sweep
    perf_hotpath
    prefix_cache
    serve_scale
    tab_latency
    telemetry_overhead
    tenant_sweep
    traffic_sweep
)
for b in "${BENCHES[@]}"; do
    echo "== bench smoke: $b =="
    cargo bench --bench "$b" -- --json --smoke
done

# Perf gate: the event-core gate row of BENCH_perf_hotpath.json is a
# fixed-size run (4 replicas × 2000 requests, smoke and full alike), so
# the fresh number is directly comparable to the committed baseline.
# Fail on a >2x wall-clock regression; CI machines are noisy enough that
# a tighter bound would flake.
echo "== perf gate: event-core 4x2000 =="
extract_gate_ns() {
    grep -o '"section": "gate"[^}]*' "$1" 2>/dev/null \
        | sed -n 's/.*"event_core_ns": \([0-9.eE+-]*\).*/\1/p' | head -n1
}
new_ns=$(extract_gate_ns BENCH_perf_hotpath.json || true)
if [[ -z "$new_ns" ]]; then
    echo "error: no gate row in BENCH_perf_hotpath.json (benches/perf_hotpath.rs must emit it)" >&2
    exit 1
fi
base_ns=""
if command -v git >/dev/null; then
    base_file=$(mktemp)
    if git show HEAD:BENCH_perf_hotpath.json > "$base_file" 2>/dev/null; then
        base_ns=$(extract_gate_ns "$base_file" || true)
    fi
    rm -f "$base_file"
fi
if [[ -n "$base_ns" ]]; then
    echo "gate: fresh ${new_ns} ns vs committed baseline ${base_ns} ns"
    if awk -v n="$new_ns" -v b="$base_ns" 'BEGIN { exit !(b > 0 && n > 2.0 * b) }'; then
        echo "error: event-core gate regressed >2x (${new_ns} ns vs ${base_ns} ns baseline) —" >&2
        echo "       find the regression, or re-baseline deliberately by committing the new JSON" >&2
        exit 1
    fi
else
    echo "notice: no committed BENCH_perf_hotpath.json baseline — commit the generated one"
    echo "        so the perf gate binds on the next run."
fi

echo
echo "smoke artifacts:"
ls -l BENCH_*.json
