#!/usr/bin/env bash
# Recompute the test-count floor that scripts/ci.sh enforces.
#
# The floor is the number of #[test] annotations under rust/, benches/
# and examples/ — a toolchain-free proxy for the suite size, so the gate
# also runs in environments without cargo. Run this after adding tests
# and commit the updated scripts/test_floor.txt; lowering the floor is a
# deliberate act that should come with justification in the PR.
set -euo pipefail
cd "$(dirname "$0")/.."

count=$(grep -rE '^\s*#\[test\]' rust benches examples --include='*.rs' | wc -l | tr -d ' ')
echo "$count" > scripts/test_floor.txt
echo "test floor set to $count (scripts/test_floor.txt) — commit it"
