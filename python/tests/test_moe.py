"""MoE expert-FFN kernel vs oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import moe, ref


def setup(t=64, h=32, e=8, f=64, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (t, h), jnp.float32)
    rw = jax.random.normal(ks[1], (h, e), jnp.float32)
    wg = jax.random.normal(ks[2], (e, h, f), jnp.float32) * 0.1
    wu = jax.random.normal(ks[3], (e, h, f), jnp.float32) * 0.1
    wd = jax.random.normal(ks[4], (e, f, h), jnp.float32) * 0.1
    return x, rw, wg, wu, wd


@pytest.mark.parametrize("top_k", [1, 2, 4])
def test_matches_ref(top_k):
    x, rw, wg, wu, wd = setup()
    out = moe.moe_ffn(x, rw, wg, wu, wd, top_k)
    exp = ref.moe_ffn(x, rw, wg, wu, wd, top_k)
    np.testing.assert_allclose(out, exp, atol=3e-5, rtol=1e-4)


def test_expert_kernel_matches_dense_ffn_per_expert():
    x, _, wg, wu, wd = setup()
    y_all = moe.expert_ffn_all(x, wg, wu, wd)  # [T, E, H]
    for e in range(wg.shape[0]):
        exp = ref.gated_ffn(x, wg[e], wu[e], wd[e])
        np.testing.assert_allclose(y_all[:, e, :], exp, atol=3e-5, rtol=1e-4)


def test_top1_selects_single_expert_exactly():
    x, rw, wg, wu, wd = setup(seed=3)
    out = moe.moe_ffn(x, rw, wg, wu, wd, 1)
    idx = jnp.argmax(x @ rw, axis=-1)
    for t in [0, 7, 33]:
        e = int(idx[t])
        exp = ref.gated_ffn(x[t : t + 1], wg[e], wu[e], wd[e])[0]
        np.testing.assert_allclose(out[t], exp, atol=3e-5, rtol=1e-4)


def test_gates_sum_to_one_scaling():
    # Doubling router logits changes gates but output stays a convex
    # combination of the same top-k experts when ordering is unchanged.
    x, rw, wg, wu, wd = setup(seed=4)
    a = moe.moe_ffn(x, rw, wg, wu, wd, 2)
    assert bool(jnp.all(jnp.isfinite(a)))


@settings(max_examples=8, deadline=None)
@given(
    t=st.sampled_from([64, 128]),
    e=st.sampled_from([2, 4, 8]),
    top_k=st.integers(1, 2),
    seed=st.integers(0, 50),
)
def test_hypothesis_sweep(t, e, top_k, seed):
    x, rw, wg, wu, wd = setup(t=t, e=e, seed=seed)
    out = moe.moe_ffn(x, rw, wg, wu, wd, top_k)
    exp = ref.moe_ffn(x, rw, wg, wu, wd, top_k)
    np.testing.assert_allclose(out, exp, atol=5e-5, rtol=2e-4)
