"""L2 model tests: shapes, causality, TP-pipeline equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


@pytest.fixture(scope="module")
def setup():
    cfg = model.TinyConfig()
    params = model.init_params(cfg)
    toks = jax.random.randint(jax.random.PRNGKey(42), (2, 64), 0, cfg.vocab)
    return cfg, params, toks


def test_forward_shapes(setup):
    cfg, params, toks = setup
    logits = model.forward(params, toks, cfg)
    assert logits.shape == (2, 64, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_param_count_matches_formula(setup):
    cfg, params, _ = setup
    n = sum(
        int(np.prod(a.shape))
        for a in jax.tree_util.tree_leaves(params)
    )
    assert n == cfg.param_count()


def test_causality(setup):
    """Changing a future token must not change past logits."""
    cfg, params, toks = setup
    base = model.forward(params, toks, cfg)
    perturbed = toks.at[:, -1].set((toks[:, -1] + 1) % cfg.vocab)
    out = model.forward(params, perturbed, cfg)
    np.testing.assert_allclose(base[:, :-1, :], out[:, :-1, :], atol=1e-5)
    assert float(jnp.max(jnp.abs(base[:, -1, :] - out[:, -1, :]))) > 1e-3


def test_tp_pipeline_matches_full_model(setup):
    """The sharded-partials-plus-accumulate pipeline (what the Rust
    coordinator executes through the TAB pool) must reproduce the full
    replicated forward."""
    cfg, params, toks = setup
    full = model.forward(params, toks, cfg)
    for tp in (2, 4):
        sharded = model.tp_forward_reference(params, toks, cfg, tp)
        np.testing.assert_allclose(full, sharded, atol=5e-4, rtol=1e-4)


def test_shard_params_partition_exactly(setup):
    cfg, params, _ = setup
    lp = params["layers"][0]
    shards = [model.shard_layer_params(lp, 4, r, cfg.heads) for r in range(4)]
    wq_cat = jnp.concatenate([s["wq"] for s in shards], axis=1)
    np.testing.assert_array_equal(wq_cat, lp["wq"])
    wo_cat = jnp.concatenate([s["wo"] for s in shards], axis=0)
    np.testing.assert_array_equal(wo_cat, lp["wo"])
    wd_cat = jnp.concatenate([s["wd"] for s in shards], axis=0)
    np.testing.assert_array_equal(wd_cat, lp["wd"])


def test_greedy_generate_extends_prompt(setup):
    cfg, params, _ = setup
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab)
    out = model.greedy_generate(params, prompt, cfg, steps=2)
    assert out.shape == (2, 66)
    np.testing.assert_array_equal(out[:, :64], prompt)


def test_deterministic_params(setup):
    cfg, _, _ = setup
    a = model.init_params(cfg, seed=0)
    b = model.init_params(cfg, seed=0)
    np.testing.assert_array_equal(a["embed"], b["embed"])
    c = model.init_params(cfg, seed=1)
    assert float(jnp.max(jnp.abs(a["embed"] - c["embed"]))) > 1e-3
