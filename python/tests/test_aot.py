"""AOT export sanity: HLO text artifacts + parameter blob consistency."""

import os

import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    aot.export(str(out))
    return str(out)


def test_all_artifacts_exist(exported):
    for name in [
        "model_fwd.hlo.txt",
        "layer_shard_fwd.hlo.txt",
        "attention.hlo.txt",
        "writeacc.hlo.txt",
        "params.bin",
        "manifest.txt",
        "meta.txt",
    ]:
        assert os.path.exists(os.path.join(exported, name)), name


def test_hlo_text_is_parseable_entry_modules(exported):
    for name in ["model_fwd", "layer_shard_fwd", "attention", "writeacc"]:
        text = open(os.path.join(exported, f"{name}.hlo.txt")).read()
        assert "ENTRY" in text, f"{name} missing ENTRY computation"
        assert "HloModule" in text
        # 64-bit-id regression guard: the text format re-assigns ids, so
        # the file must be plain text, not protobuf bytes.
        assert text.isprintable() or "\n" in text


def test_manifest_matches_blob_size(exported):
    blob = os.path.getsize(os.path.join(exported, "params.bin"))
    total = 0
    names = set()
    for line in open(os.path.join(exported, "manifest.txt")):
        parts = line.split()
        name, _offset = parts[0], int(parts[1])
        shape = [int(d) for d in parts[2:]]
        total += int(np.prod(shape))
        names.add(name)
    assert total * 4 == blob
    assert "embed" in names
    assert "shard.0.r0.wq" in names
    assert f"layers.{model.TinyConfig().layers - 1}.wd" in names


def test_manifest_offsets_are_cumulative(exported):
    expected = 0
    for line in open(os.path.join(exported, "manifest.txt")):
        parts = line.split()
        offset = int(parts[1])
        shape = [int(d) for d in parts[2:]]
        assert offset == expected, parts[0]
        expected += int(np.prod(shape))


def test_blob_roundtrips_embed(exported):
    cfg = model.TinyConfig()
    params = model.init_params(cfg)
    blob = np.fromfile(os.path.join(exported, "params.bin"), dtype="<f4")
    embed = blob[: cfg.vocab * cfg.hidden].reshape(cfg.vocab, cfg.hidden)
    np.testing.assert_array_equal(embed, np.asarray(params["embed"]))


def test_meta_values(exported):
    meta = dict(
        line.split() for line in open(os.path.join(exported, "meta.txt"))
    )
    cfg = model.TinyConfig()
    assert int(meta["vocab"]) == cfg.vocab
    assert int(meta["layers"]) == cfg.layers
    assert int(meta["tp"]) == aot.TP
