"""L1 attention kernel vs pure-jnp oracle — the core correctness signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as attn_k
from compile.kernels import ref


def rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


@pytest.mark.parametrize("b,h,s,d", [(1, 1, 64, 16), (2, 4, 64, 32), (1, 8, 128, 32)])
def test_matches_ref_causal(b, h, s, d):
    q, k, v = (rand(i, (b, h, s, d), jnp.float32) for i in range(3))
    out = attn_k.flash_attention(q, k, v, causal=True)
    exp = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(out, exp, atol=3e-5, rtol=1e-4)


@pytest.mark.parametrize("b,h,s,d", [(1, 2, 64, 16), (2, 2, 128, 64)])
def test_matches_ref_noncausal(b, h, s, d):
    q, k, v = (rand(i + 10, (b, h, s, d), jnp.float32) for i in range(3))
    out = attn_k.flash_attention(q, k, v, causal=False)
    exp = ref.attention(q, k, v, causal=False)
    np.testing.assert_allclose(out, exp, atol=3e-5, rtol=1e-4)


def test_decode_shape_s1_attends_to_full_context():
    # Decode: S (=64 block min) shorter than T; offset handling must let the
    # last query row see every key.
    q = rand(1, (1, 2, 64, 16), jnp.float32)
    k = rand(2, (1, 2, 128, 16), jnp.float32)
    v = rand(3, (1, 2, 128, 16), jnp.float32)
    out = attn_k.flash_attention(q, k, v, causal=True)
    exp = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(out, exp, atol=3e-5, rtol=1e-4)


def test_block_size_invariance():
    q, k, v = (rand(i + 20, (1, 2, 128, 32), jnp.float32) for i in range(3))
    a = attn_k.flash_attention(q, k, v, block_q=32, block_k=32)
    b = attn_k.flash_attention(q, k, v, block_q=128, block_k=64)
    np.testing.assert_allclose(a, b, atol=3e-5, rtol=1e-4)


def test_bf16_runs_with_loose_tolerance():
    q, k, v = (rand(i + 30, (1, 2, 64, 32), jnp.bfloat16) for i in range(3))
    out = attn_k.flash_attention(q, k, v)
    exp = ref.attention(q, k, v)
    np.testing.assert_allclose(
        out.astype(jnp.float32), exp.astype(jnp.float32), atol=3e-2, rtol=3e-2
    )


def test_rejects_non_tiling_lengths():
    q = rand(0, (1, 1, 65, 16), jnp.float32)
    with pytest.raises(ValueError, match="tile"):
        attn_k.flash_attention(q, q, q, block_q=64, block_k=64)


@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 2),
    h=st.sampled_from([1, 2, 4]),
    sq=st.sampled_from([64, 128]),
    extra_ctx=st.sampled_from([0, 64, 128]),
    d=st.sampled_from([16, 32, 64]),
    causal=st.booleans(),
    seed=st.integers(0, 1000),
)
def test_hypothesis_shape_sweep(b, h, sq, extra_ctx, d, causal, seed):
    """Property sweep across shapes/dtypes: kernel ≡ oracle."""
    t = sq + extra_ctx
    kq = jax.random.PRNGKey(seed)
    ks = jax.random.split(kq, 3)
    q = jax.random.normal(ks[0], (b, h, sq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, h, t, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, h, t, d), jnp.float32)
    out = attn_k.flash_attention(q, k, v, causal=causal)
    exp = ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(out, exp, atol=5e-5, rtol=2e-4)


def test_softmax_rows_bounded():
    # Output is a convex combination of V rows → within [min(V), max(V)].
    q, k, v = (rand(i + 40, (1, 1, 64, 16), jnp.float32) for i in range(3))
    out = attn_k.flash_attention(q, k, v, causal=False)
    assert float(jnp.max(out)) <= float(jnp.max(v)) + 1e-5
    assert float(jnp.min(out)) >= float(jnp.min(v)) - 1e-5


def test_vmem_footprint_estimate_reasonable():
    # 64×64 f32 tiles with d=128: well under the ~16 MiB VMEM of a TPU core.
    bytes_ = attn_k.vmem_footprint_bytes(64, 64, 4096, 128)
    assert bytes_ < 16 * 1024 * 1024
    assert bytes_ > 0
