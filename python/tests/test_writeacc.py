"""Write-accumulate kernel vs oracle: the TAB reduction contract."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, writeacc


@pytest.mark.parametrize("n,length", [(1, 1024), (4, 4096), (8, 2048)])
def test_matches_ref(n, length):
    c = jax.random.normal(jax.random.PRNGKey(n), (n, length), jnp.float32)
    out = writeacc.write_accumulate(c)
    np.testing.assert_allclose(out, ref.write_accumulate(c), atol=1e-5, rtol=1e-5)


def test_commutativity():
    """§3.3.1: accumulation is order-independent — permuting contributors
    must not change the result (up to float associativity at this scale)."""
    c = jax.random.normal(jax.random.PRNGKey(7), (6, 1024), jnp.float32)
    perm = jnp.array([3, 0, 5, 1, 4, 2])
    a = writeacc.write_accumulate(c)
    b = writeacc.write_accumulate(c[perm])
    np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)


def test_zero_contributions():
    c = jnp.zeros((4, 1024), jnp.float32)
    assert float(jnp.max(jnp.abs(writeacc.write_accumulate(c)))) == 0.0


def test_single_contributor_is_identity():
    c = jax.random.normal(jax.random.PRNGKey(9), (1, 2048), jnp.float32)
    np.testing.assert_allclose(writeacc.write_accumulate(c), c[0], atol=0, rtol=0)


def test_rejects_non_tiling():
    c = jnp.ones((2, 1000), jnp.float32)
    with pytest.raises(ValueError, match="tile"):
        writeacc.write_accumulate(c, block=512)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(1, 8),
    blocks=st.integers(1, 4),
    block=st.sampled_from([256, 512, 1024]),
    seed=st.integers(0, 100),
)
def test_hypothesis_sweep(n, blocks, block, seed):
    c = jax.random.normal(jax.random.PRNGKey(seed), (n, blocks * block), jnp.float32)
    out = writeacc.write_accumulate(c, block=block)
    np.testing.assert_allclose(out, jnp.sum(c, axis=0), atol=2e-5, rtol=1e-4)
