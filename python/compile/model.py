"""L2 JAX model: a tiny GPT-style transformer built on the L1 kernels.

Two build targets:

* ``forward`` — the full replicated model (embed → L blocks → norm → lm
  head). AOT-exported as ``model_fwd.hlo.txt``; the Rust serving example
  uses it for decode (recompute-style generation) and as the numerical
  oracle for the sharded pipeline.
* ``layer_shard_forward`` — ONE transformer block with tensor-parallel
  sharded weights (heads and FFN columns split across `tp` workers),
  producing a *partial* residual contribution. Each Rust worker executes
  this artifact for its shard; the partial outputs are summed through the
  functional TAB pool (write-accumulate) — the paper's "communication
  collapsed into memory ops" path, end to end. Exported as
  ``layer_shard_fwd.hlo.txt``.

Weights are explicit function arguments (not baked constants), so the same
HLO serves any parameter values the coordinator supplies.

The real workloads (GPT-3 175B / Grok-1 / Qwen3-235B) obviously cannot run
through a CPU PJRT plugin; DESIGN.md §1 documents this substitution — the
tiny model proves the three-layer stack composes, while the simulator
reproduces the paper-scale numbers.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from .kernels import attention as attn_k
from .kernels import ref


@dataclasses.dataclass(frozen=True)
class TinyConfig:
    """Architecture of the end-to-end demo model (~4.3M params)."""

    vocab: int = 512
    layers: int = 4
    hidden: int = 256
    heads: int = 8
    ffn: int = 1024
    max_seq: int = 128

    @property
    def head_dim(self) -> int:
        return self.hidden // self.heads

    def param_count(self) -> int:
        per_layer = (
            4 * self.hidden * self.hidden
            + 3 * self.hidden * self.ffn
            + 2 * self.hidden  # the two norm vectors
        )
        return self.vocab * self.hidden + self.layers * per_layer + self.hidden


def init_params(cfg: TinyConfig, seed: int = 0) -> dict:
    """Deterministic parameter pytree (dict of arrays, f32)."""
    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, 2 + cfg.layers)
    scale = 1.0 / math.sqrt(cfg.hidden)
    params: dict = {
        "embed": jax.random.normal(keys[0], (cfg.vocab, cfg.hidden), jnp.float32) * scale,
        "final_norm": jnp.ones((cfg.hidden,), jnp.float32),
        "layers": [],
    }
    for l in range(cfg.layers):
        lk = jax.random.split(keys[2 + l], 7)
        params["layers"].append(
            {
                "norm1": jnp.ones((cfg.hidden,), jnp.float32),
                "norm2": jnp.ones((cfg.hidden,), jnp.float32),
                "wq": jax.random.normal(lk[0], (cfg.hidden, cfg.hidden), jnp.float32) * scale,
                "wk": jax.random.normal(lk[1], (cfg.hidden, cfg.hidden), jnp.float32) * scale,
                "wv": jax.random.normal(lk[2], (cfg.hidden, cfg.hidden), jnp.float32) * scale,
                "wo": jax.random.normal(lk[3], (cfg.hidden, cfg.hidden), jnp.float32) * scale,
                "wg": jax.random.normal(lk[4], (cfg.hidden, cfg.ffn), jnp.float32) * scale,
                "wu": jax.random.normal(lk[5], (cfg.hidden, cfg.ffn), jnp.float32) * scale,
                "wd": jax.random.normal(lk[6], (cfg.ffn, cfg.hidden), jnp.float32) * scale,
            }
        )
    return params


def _split_heads(x: jax.Array, heads: int) -> jax.Array:
    b, s, h = x.shape
    return x.reshape(b, s, heads, h // heads).transpose(0, 2, 1, 3)  # [B,He,S,D]


def _merge_heads(x: jax.Array) -> jax.Array:
    b, he, s, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, he * d)


def block_forward(x: jax.Array, lp: dict, heads: int, *, interpret: bool = True) -> jax.Array:
    """One full (unsharded) transformer block with pre-norm residuals."""
    h1 = ref.rmsnorm(x, lp["norm1"])
    q = _split_heads(h1 @ lp["wq"], heads)
    k = _split_heads(h1 @ lp["wk"], heads)
    v = _split_heads(h1 @ lp["wv"], heads)
    a = attn_k.flash_attention(q, k, v, causal=True, interpret=interpret)
    x = x + _merge_heads(a) @ lp["wo"]
    h2 = ref.rmsnorm(x, lp["norm2"])
    x = x + ref.gated_ffn(h2, lp["wg"], lp["wu"], lp["wd"])
    return x


def forward(params: dict, tokens: jax.Array, cfg: TinyConfig, *, interpret: bool = True) -> jax.Array:
    """Full model: tokens [B, S] int32 → logits [B, S, V]."""
    x = params["embed"][tokens]
    for lp in params["layers"]:
        x = block_forward(x, lp, cfg.heads, interpret=interpret)
    x = ref.rmsnorm(x, params["final_norm"])
    return x @ params["embed"].T


# ---------------------------------------------------------------------------
# Tensor-parallel shard (the artifact each Rust worker executes).
# ---------------------------------------------------------------------------


def shard_layer_params(lp: dict, tp: int, rank: int, heads: int) -> dict:
    """Megatron-style split of one layer's weights for worker `rank`.

    Column-parallel: wq/wk/wv (by heads) and wg/wu (by FFN columns).
    Row-parallel: wo and wd (by input rows). Norm weights are replicated.
    """
    hd = lp["wq"].shape[1] // heads
    hpr = heads // tp  # heads per rank
    cs = slice(rank * hpr * hd, (rank + 1) * hpr * hd)
    f = lp["wg"].shape[1]
    fpr = f // tp
    fs = slice(rank * fpr, (rank + 1) * fpr)
    return {
        "norm1": lp["norm1"],
        "norm2": lp["norm2"],
        "wq": lp["wq"][:, cs],
        "wk": lp["wk"][:, cs],
        "wv": lp["wv"][:, cs],
        "wo": lp["wo"][cs, :],
        "wg": lp["wg"][:, fs],
        "wu": lp["wu"][:, fs],
        "wd": lp["wd"][fs, :],
    }


def make_shard_fn(cfg: TinyConfig, tp: int, *, interpret: bool = True):
    """Build the shard-forward function for a fixed (cfg, tp).

    Signature: (x, norm1, norm2, wq, wk, wv, wo, wg, wu, wd) →
    (attn_partial [B,S,H], ffn_partial [B,S,H]).
    """
    shard_heads = cfg.heads // tp

    def shard_fwd(x, norm1, norm2, wq, wk, wv, wo, wg, wu, wd):
        h1 = ref.rmsnorm(x, norm1)
        q = _split_heads(h1 @ wq, shard_heads)
        k = _split_heads(h1 @ wk, shard_heads)
        v = _split_heads(h1 @ wv, shard_heads)
        a = attn_k.flash_attention(q, k, v, causal=True, interpret=interpret)
        attn_partial = _merge_heads(a) @ wo
        h2 = ref.rmsnorm(x, norm2)
        ffn_partial = ref.gated_ffn(h2, wg, wu, wd)
        return attn_partial, ffn_partial

    return shard_fwd


def tp_forward_reference(
    params: dict, tokens: jax.Array, cfg: TinyConfig, tp: int, *, interpret: bool = True
) -> jax.Array:
    """Pure-python reference of the TP pipeline the Rust coordinator runs:
    shard partials summed (the TAB write-accumulate), residuals applied in
    order. Must match ``forward`` up to float-accumulation order.
    """
    shard_fn = make_shard_fn(cfg, tp, interpret=interpret)
    x = params["embed"][tokens]
    for lp in params["layers"]:
        shards = [shard_layer_params(lp, tp, r, cfg.heads) for r in range(tp)]
        attn_sum = None
        for sp in shards:
            ap, _ = shard_fn(
                x, sp["norm1"], sp["norm2"], sp["wq"], sp["wk"], sp["wv"],
                sp["wo"], sp["wg"], sp["wu"], sp["wd"],
            )
            attn_sum = ap if attn_sum is None else attn_sum + ap
        x = x + attn_sum
        ffn_sum = None
        for sp in shards:
            _, fp = shard_fn(
                x, sp["norm1"], sp["norm2"], sp["wq"], sp["wk"], sp["wv"],
                sp["wo"], sp["wg"], sp["wu"], sp["wd"],
            )
            ffn_sum = fp if ffn_sum is None else ffn_sum + fp
        x = x + ffn_sum
    x = ref.rmsnorm(x, params["final_norm"])
    return x @ params["embed"].T


def greedy_generate(
    params: dict,
    prompt: jax.Array,
    cfg: TinyConfig,
    steps: int,
    *,
    interpret: bool = True,
) -> jax.Array:
    """Greedy decoding by full-prefix recompute (the strategy the serving
    example uses: simple, artifact-friendly; KV-cache decode is listed as
    future work in DESIGN.md)."""
    tokens = prompt
    for _ in range(steps):
        cur = tokens.shape[1]
        # Pad right to the attention tile size; causality makes the padded
        # positions invisible to position cur−1.
        padded_len = -(-cur // 64) * 64
        padded = jnp.pad(tokens, ((0, 0), (0, padded_len - cur)))
        logits = forward(params, padded, cfg, interpret=interpret)
        nxt = jnp.argmax(logits[:, cur - 1, :], axis=-1).astype(tokens.dtype)
        tokens = jnp.concatenate([tokens, nxt[:, None]], axis=1)
    return tokens
