"""AOT export: lower the L2/L1 graphs to HLO text + parameter blobs.

Python runs ONCE, at build time (`make artifacts`); the Rust coordinator
loads these artifacts through PJRT and never touches Python again.

Interchange format is HLO **text**, not serialized HloModuleProto: jax
≥ 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Outputs (under --out-dir, default ../artifacts):
  model_fwd.hlo.txt        full tiny-transformer forward  (tokens→logits)
  layer_shard_fwd.hlo.txt  one TP-sharded block (partial sums for the TAB)
  attention.hlo.txt        standalone L1 attention kernel
  writeacc.hlo.txt         standalone L1 write-accumulate kernel
  params.bin               f32 LE parameter blob (full + per-rank shards)
  manifest.txt             tensor table:  name offset_elems shape...
  meta.txt                 model/config scalars for the Rust loader
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import attention as attn_k
from .kernels import writeacc as wa_k

# Export shapes (static — one compiled executable per variant).
BATCH = 4
SEQ = 64
TP = 4
WRITEACC_LANES = 65536


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def flatten_params(params: dict, cfg: model.TinyConfig) -> list[tuple[str, jax.Array]]:
    """Deterministic (name, array) order shared with the Rust loader."""
    out = [("embed", params["embed"]), ("final_norm", params["final_norm"])]
    keys = ["norm1", "norm2", "wq", "wk", "wv", "wo", "wg", "wu", "wd"]
    for l, lp in enumerate(params["layers"]):
        for k in keys:
            out.append((f"layers.{l}.{k}", lp[k]))
    # Per-rank shard tensors (the Rust workers feed these to the shard HLO).
    for l, lp in enumerate(params["layers"]):
        for r in range(TP):
            sp = model.shard_layer_params(lp, TP, r, cfg.heads)
            for k in keys:
                out.append((f"shard.{l}.r{r}.{k}", sp[k]))
    return out


def export(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    cfg = model.TinyConfig()
    params = model.init_params(cfg)

    # ---- model_fwd: (tokens, *param_arrays) → logits --------------------
    flat_full = [
        ("embed", params["embed"]),
        ("final_norm", params["final_norm"]),
    ]
    keys = ["norm1", "norm2", "wq", "wk", "wv", "wo", "wg", "wu", "wd"]
    for lp in params["layers"]:
        for k in keys:
            flat_full.append((k, lp[k]))

    def fwd_flat(tokens, *arrays):
        p = {
            "embed": arrays[0],
            "final_norm": arrays[1],
            "layers": [
                dict(zip(keys, arrays[2 + i * len(keys) : 2 + (i + 1) * len(keys)]))
                for i in range(cfg.layers)
            ],
        }
        return (model.forward(p, tokens, cfg),)

    tok_spec = jax.ShapeDtypeStruct((BATCH, SEQ), jnp.int32)
    arr_specs = [jax.ShapeDtypeStruct(a.shape, a.dtype) for _, a in flat_full]
    lowered = jax.jit(fwd_flat).lower(tok_spec, *arr_specs)
    path = os.path.join(out_dir, "model_fwd.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    print(f"wrote {path}")

    # ---- layer_shard_fwd: (x, 9 shard weights) → (attn_partial, ffn_partial)
    shard_fn = model.make_shard_fn(cfg, TP)
    x_spec = jax.ShapeDtypeStruct((BATCH, SEQ, cfg.hidden), jnp.float32)
    sp0 = model.shard_layer_params(params["layers"][0], TP, 0, cfg.heads)
    shard_specs = [jax.ShapeDtypeStruct(sp0[k].shape, sp0[k].dtype) for k in keys]
    lowered = jax.jit(lambda x, *w: shard_fn(x, *w)).lower(x_spec, *shard_specs)
    path = os.path.join(out_dir, "layer_shard_fwd.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    print(f"wrote {path}")

    # ---- standalone kernels ---------------------------------------------
    q_spec = jax.ShapeDtypeStruct((1, cfg.heads, SEQ, cfg.head_dim), jnp.float32)
    lowered = jax.jit(
        lambda q, k, v: (attn_k.flash_attention(q, k, v),)
    ).lower(q_spec, q_spec, q_spec)
    path = os.path.join(out_dir, "attention.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    print(f"wrote {path}")

    c_spec = jax.ShapeDtypeStruct((TP, WRITEACC_LANES), jnp.float32)
    lowered = jax.jit(lambda c: (wa_k.write_accumulate(c),)).lower(c_spec)
    path = os.path.join(out_dir, "writeacc.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    print(f"wrote {path}")

    # ---- parameter blob + manifest --------------------------------------
    tensors = flatten_params(params, cfg)
    blob_path = os.path.join(out_dir, "params.bin")
    manifest_path = os.path.join(out_dir, "manifest.txt")
    offset = 0
    with open(blob_path, "wb") as blob, open(manifest_path, "w") as man:
        for name, arr in tensors:
            a = np.asarray(arr, dtype="<f4")
            blob.write(a.tobytes())
            shape = " ".join(str(d) for d in a.shape)
            man.write(f"{name} {offset} {shape}\n")
            offset += a.size
    print(f"wrote {blob_path} ({offset * 4 / 1e6:.1f} MB) + manifest")

    meta_path = os.path.join(out_dir, "meta.txt")
    with open(meta_path, "w") as f:
        f.write(
            f"vocab {cfg.vocab}\nlayers {cfg.layers}\nhidden {cfg.hidden}\n"
            f"heads {cfg.heads}\nffn {cfg.ffn}\nbatch {BATCH}\nseq {SEQ}\n"
            f"tp {TP}\nwriteacc_lanes {WRITEACC_LANES}\n"
            f"param_count {cfg.param_count()}\n"
        )
    print(f"wrote {meta_path}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    # Back-compat with `make artifacts` single-file target.
    ap.add_argument("--out", default=None, help="(ignored; kept for Makefile stamp)")
    args = ap.parse_args()
    out_dir = args.out_dir
    if args.out is not None:
        out_dir = os.path.dirname(args.out) or "."
    export(out_dir)


if __name__ == "__main__":
    main()
