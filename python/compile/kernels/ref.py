"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package is validated against these functions at build
time (pytest); the kernels themselves lower (interpret=True) into the HLO
artifacts the Rust runtime executes. Keeping the oracles dependency-free
jnp makes the correctness contract auditable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True) -> jax.Array:
    """Scaled dot-product attention.

    Shapes: q [B, H, S, D], k/v [B, H, T, D] → [B, H, S, D].
    With ``causal=True`` query i attends to keys ≤ i + (T − S) (so decode
    steps with S=1, T=ctx attend to everything).
    """
    d = q.shape[-1]
    scores = jnp.einsum("bhsd,bhtd->bhst", q, k) / jnp.sqrt(jnp.asarray(d, q.dtype))
    if causal:
        s_len, t_len = q.shape[2], k.shape[2]
        offset = t_len - s_len
        qi = jnp.arange(s_len)[:, None]
        kj = jnp.arange(t_len)[None, :]
        mask = kj <= qi + offset
        scores = jnp.where(mask, scores, jnp.asarray(-jnp.inf, scores.dtype))
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bhst,bhtd->bhsd", probs, v)


def write_accumulate(contributions: jax.Array) -> jax.Array:
    """TAB in-memory reduction: sum over the leading (xPU) axis.

    ``contributions`` has shape [N, ...]; the result is the element-wise
    sum — the value every xPU reads back after an AllReduce through
    FengHuang Remote Memory.
    """
    return jnp.sum(contributions, axis=0)


def gated_ffn(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    """SwiGLU-style gated FFN: (silu(x·Wg) * (x·Wu)) · Wd."""
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down


def moe_ffn(
    x: jax.Array,
    router_w: jax.Array,
    w_gate: jax.Array,
    w_up: jax.Array,
    w_down: jax.Array,
    top_k: int,
) -> jax.Array:
    """Top-k mixture-of-experts FFN (dense compute, sparse combine).

    x [T, H]; router_w [H, E]; w_gate/w_up [E, H, F]; w_down [E, F, H].
    Router probabilities are renormalised over the selected top-k.
    """
    logits = x @ router_w  # [T, E]
    top_vals, top_idx = jax.lax.top_k(logits, top_k)  # [T, k]
    gates = jax.nn.softmax(top_vals.astype(jnp.float32), axis=-1).astype(x.dtype)
    # Dense evaluation of every expert (reference path — O(T·E·H·F)).
    h_gate = jnp.einsum("th,ehf->tef", x, w_gate)
    h_up = jnp.einsum("th,ehf->tef", x, w_up)
    h = jax.nn.silu(h_gate) * h_up
    y_all = jnp.einsum("tef,efh->teh", h, w_down)  # [T, E, H]
    t = x.shape[0]
    sel = y_all[jnp.arange(t)[:, None], top_idx]  # [T, k, H]
    return jnp.einsum("tkh,tk->th", sel, gates)


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMS normalisation."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w
