"""L1 Pallas kernel: blocked causal attention with online softmax.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's workloads
run FlashAttention-3 on H200s — warps, tensor cores, shared-memory tiles.
On TPU the same insight (never materialise the S×T score matrix; stream K/V
tiles through fast memory) maps to:

* **BlockSpec → VMEM staging**: each grid step receives one query tile and
  the K/V stream for its (batch, head) in VMEM — VMEM plays the role the
  paper gives xPU local memory, with the HBM↔VMEM schedule expressed
  declaratively instead of with threadblocks;
* **MXU-shaped tiles**: the default 64×64 query/key blocks keep the two
  matmuls MXU-major (the systolic array wants ≥128-lane multiples; head_dim
  is the lane axis);
* **online softmax carry** replaces the warp-level reductions of the CUDA
  formulation.

``interpret=True`` is mandatory here: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute, while interpret mode lowers
to plain HLO that both pytest and the Rust runtime can run (see
/opt/xla-example/README.md).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, causal: bool, offset: int):
    """One (batch·head, q-block) grid step.

    q_ref: [1, 1, block_q, D]; k_ref/v_ref: [1, 1, T, D];
    o_ref: [1, 1, block_q, D].
    """
    block_q = q_ref.shape[2]
    t_len = k_ref.shape[2]
    d = q_ref.shape[3]
    q = q_ref[0, 0].astype(jnp.float32) * (1.0 / math.sqrt(d))  # [bq, D]

    num_kb = t_len // block_k
    q_block_idx = pl.program_id(1)
    q_pos = q_block_idx * block_q + jax.lax.iota(jnp.int32, block_q)  # global q rows

    def body(kb, carry):
        m_prev, l_prev, acc = carry
        k_tile = k_ref[0, 0, pl.dslice(kb * block_k, block_k), :].astype(jnp.float32)
        v_tile = v_ref[0, 0, pl.dslice(kb * block_k, block_k), :].astype(jnp.float32)
        s = q @ k_tile.T  # [bq, bk]
        if causal:
            k_pos = kb * block_k + jax.lax.iota(jnp.int32, block_k)
            mask = k_pos[None, :] <= q_pos[:, None] + offset
            s = jnp.where(mask, s, NEG_INF)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_cur = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v_tile
        return m_cur, l_cur, acc

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, d), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, num_kb, body, (m0, l0, acc0))
    out = acc / jnp.maximum(l, 1e-20)[:, None]
    o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 64,
    block_k: int = 64,
    interpret: bool = True,
) -> jax.Array:
    """Blocked attention. q [B,H,S,D], k/v [B,H,T,D] → [B,H,S,D].

    S must divide by block_q and T by block_k (the trace generator and the
    model always pad to tile multiples — the same constraint MXU tiling
    imposes on the real hardware).
    """
    b, h, s_len, d = q.shape
    t_len = k.shape[2]
    block_q = min(block_q, s_len)
    block_k = min(block_k, t_len)
    if s_len % block_q or t_len % block_k:
        raise ValueError(
            f"sequence lengths must tile: S={s_len} %% {block_q}, T={t_len} %% {block_k}"
        )
    offset = t_len - s_len
    grid = (b * h, s_len // block_q)
    kernel = functools.partial(
        _attn_kernel, block_k=block_k, causal=causal, offset=offset
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda i, j: (i // h, i % h, j, 0)),
            pl.BlockSpec((1, 1, t_len, d), lambda i, j: (i // h, i % h, 0, 0)),
            pl.BlockSpec((1, 1, t_len, d), lambda i, j: (i // h, i % h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda i, j: (i // h, i % h, j, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)


def vmem_footprint_bytes(
    block_q: int, block_k: int, t_len: int, d: int, dtype_bytes: int = 4
) -> int:
    """Estimated VMEM working set of one grid step (for DESIGN.md §Perf:
    interpret-mode wallclock is not a TPU proxy, so we reason about the
    kernel's memory structure analytically).

    One query tile + the K/V stream tiles + softmax carries + accumulator.
    """
    q_tile = block_q * d * dtype_bytes
    kv_tiles = 2 * block_k * d * dtype_bytes
    carries = block_q * (2 + d) * 4  # m, l, acc in f32
    out_tile = block_q * d * dtype_bytes
    # K/V whole-stream residency is avoided: only the current tile is live.
    del t_len
    return q_tile + kv_tiles + carries + out_tile
