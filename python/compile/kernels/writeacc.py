"""L1 Pallas kernel: TAB write-accumulate (in-memory tensor reduction).

The TAB performs line-rate accumulation of tensors written by multiple
xPUs into the same shared-memory region (§3.3.1): each write-accumulate is
commutative, so the hardware needs no write ordering. This kernel is the
L1 expression of that contract — a grid dimension ranges over the N
contributing xPUs and accumulates each contribution into one output block.
Grid-carried accumulation into an output ref across grid steps is exactly
the "no ordering, just +=" semantics the TAB guarantees.

Tile shape: contributions are striped into `block` chunks (the uniform
striping of §3.3.1) so each grid step touches one VMEM-resident tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _writeacc_kernel(x_ref, o_ref):
    """Grid (N, num_blocks): accumulate contributor i's block j."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += x_ref[0, :].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def write_accumulate(
    contributions: jax.Array, *, block: int = 1024, interpret: bool = True
) -> jax.Array:
    """Sum ``contributions`` [N, L] over axis 0 via grid accumulation.

    L must divide by ``block`` (stripe granularity).
    """
    n, length = contributions.shape
    block = min(block, length)
    if length % block:
        raise ValueError(f"length {length} must tile by block {block}")
    grid = (n, length // block)
    return pl.pallas_call(
        _writeacc_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, block), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block,), lambda i, j: (j,)),
        out_shape=jax.ShapeDtypeStruct((length,), jnp.float32),
        interpret=interpret,
    )(contributions)
