"""L1 Pallas kernel: per-expert gated FFN (MoE hot loop).

The paper's MoE workloads (Grok-1, Qwen3) spend their decode bytes on
expert FFN weights. This kernel computes the gated FFN of every expert in
a grid over (expert, token-tile) — each grid step stages one expert's
weight panel and one token tile in VMEM, mirroring how the Tensor
Prefetcher pages one expert at a time through xPU local memory. The sparse
top-k combine stays in jnp (it is bandwidth-trivial).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _expert_ffn_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref):
    """Grid (E, num_token_tiles): expert e over token tile t."""
    x = x_ref[...].astype(jnp.float32)  # [bt, H]
    wg = wg_ref[0].astype(jnp.float32)  # [H, F]
    wu = wu_ref[0].astype(jnp.float32)
    wd = wd_ref[0].astype(jnp.float32)  # [F, H]
    h = jax.nn.silu(x @ wg) * (x @ wu)
    o_ref[0] = (h @ wd).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def expert_ffn_all(
    x: jax.Array,
    w_gate: jax.Array,
    w_up: jax.Array,
    w_down: jax.Array,
    *,
    block_t: int = 64,
    interpret: bool = True,
) -> jax.Array:
    """Dense per-expert gated FFN: x [T,H], w_gate/w_up [E,H,F],
    w_down [E,F,H] → [T,E,H] (every expert applied to every token).
    """
    t_len, hidden = x.shape
    e = w_gate.shape[0]
    f = w_gate.shape[2]
    block_t = min(block_t, t_len)
    if t_len % block_t:
        raise ValueError(f"tokens {t_len} must tile by {block_t}")
    grid = (e, t_len // block_t)
    out = pl.pallas_call(
        _expert_ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, hidden), lambda ei, ti: (ti, 0)),
            pl.BlockSpec((1, hidden, f), lambda ei, ti: (ei, 0, 0)),
            pl.BlockSpec((1, hidden, f), lambda ei, ti: (ei, 0, 0)),
            pl.BlockSpec((1, f, hidden), lambda ei, ti: (ei, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_t, hidden), lambda ei, ti: (ei, ti, 0)),
        out_shape=jax.ShapeDtypeStruct((e, t_len, hidden), x.dtype),
        interpret=interpret,
    )(x, w_gate, w_up, w_down)
    return jnp.transpose(out, (1, 0, 2))  # [T, E, H]


def moe_ffn(
    x: jax.Array,
    router_w: jax.Array,
    w_gate: jax.Array,
    w_up: jax.Array,
    w_down: jax.Array,
    top_k: int,
    *,
    interpret: bool = True,
) -> jax.Array:
    """Full MoE layer using the Pallas expert kernel + jnp top-k combine.

    Matches ``ref.moe_ffn`` bit-for-bit up to accumulation order.
    """
    logits = x @ router_w
    top_vals, top_idx = jax.lax.top_k(logits, top_k)
    gates = jax.nn.softmax(top_vals.astype(jnp.float32), axis=-1).astype(x.dtype)
    y_all = expert_ffn_all(x, w_gate, w_up, w_down, interpret=interpret)  # [T,E,H]
    t = x.shape[0]
    sel = y_all[jnp.arange(t)[:, None], top_idx]  # [T,k,H]
    return jnp.einsum("tkh,tk->th", sel, gates)
