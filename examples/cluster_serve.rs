//! Rack-scale serving demo: route a multi-session workload across a
//! fleet of simulated FH4 nodes, then compare the same fleet in
//! disaggregated prefill/decode mode.
//!
//! ```bash
//! cargo run --release --example cluster_serve
//! # or, equivalently, via the CLI:
//! fenghuang serve --replicas 4 --policy kv-affinity
//! ```

use fenghuang::coordinator::cluster::{session_workload, Cluster, ClusterConfig};
use fenghuang::coordinator::router::Policy;
use fenghuang::models::arch::gpt3_175b;
use fenghuang::units::Seconds;

fn main() -> fenghuang::Result<()> {
    let model = gpt3_175b();
    let workload = || session_workload(32, 8, 1024, 64, Seconds::ms(5.0));

    println!("== 4-replica FH4 rack, three routing policies ==");
    for policy in [Policy::RoundRobin, Policy::LeastLoaded, Policy::KvAffinity] {
        let cfg = ClusterConfig { policy, ..Default::default() };
        let mut cluster = Cluster::fh4(4, &model, cfg)?;
        let report = cluster.run(workload())?;
        println!("{}", report.summary());
    }

    println!("== same rack, disaggregated 2 prefill : 2 decode ==");
    let cfg = ClusterConfig {
        policy: Policy::LeastLoaded,
        max_batch: 8,
        disaggregate: Some((2, 2)),
        ..Default::default()
    };
    let mut cluster = Cluster::fh4(4, &model, cfg)?;
    let report = cluster.run(workload())?;
    println!("{}", report.summary());

    println!("== same rack under per-replica KV capacity pressure (4 GB budget) ==");
    let cfg = ClusterConfig {
        kv_budget: Some(fenghuang::units::Bytes::gb(4.0)),
        ..Default::default()
    };
    let mut cluster = Cluster::fh4(4, &model, cfg)?;
    let report = cluster.run(workload())?;
    println!("{}", report.summary());
    Ok(())
}
