//! Rack-scale serving demo: route a multi-session workload across a
//! fleet of simulated FH4 nodes, then compare the same fleet in
//! disaggregated prefill/decode mode.
//!
//! ```bash
//! cargo run --release --example cluster_serve
//! # or, equivalently, via the CLI:
//! fenghuang serve --replicas 4 --policy kv-affinity
//! fenghuang serve --replicas 8 --qps 12 --pattern diurnal --mix chat+rag --autoscale --seed 7
//! ```

use fenghuang::coordinator::cluster::{session_workload, Cluster, ClusterConfig};
use fenghuang::coordinator::router::Policy;
use fenghuang::coordinator::{AutoscaleConfig, PrefixCacheConfig};
use fenghuang::fabric::contention::{ContentionConfig, ContentionMode};
use fenghuang::models::arch::gpt3_175b;
use fenghuang::traffic::{self, ArrivalConfig, ArrivalPattern, TrafficConfig, WorkloadMix};
use fenghuang::units::Seconds;

fn main() -> fenghuang::Result<()> {
    let model = gpt3_175b();
    let workload = || session_workload(32, 8, 1024, 64, Seconds::ms(5.0));

    println!("== 4-replica FH4 rack, three routing policies ==");
    for policy in [Policy::RoundRobin, Policy::LeastLoaded, Policy::KvAffinity] {
        let cfg = ClusterConfig { policy, ..Default::default() };
        let mut cluster = Cluster::fh4(4, &model, cfg)?;
        let report = cluster.run(workload())?;
        println!("{}", report.summary());
    }

    println!("== same rack, disaggregated 2 prefill : 2 decode ==");
    let cfg = ClusterConfig {
        policy: Policy::LeastLoaded,
        max_batch: 8,
        disaggregate: Some((2, 2)),
        ..Default::default()
    };
    let mut cluster = Cluster::fh4(4, &model, cfg)?;
    let report = cluster.run(workload())?;
    println!("{}", report.summary());

    println!("== same rack under per-replica KV capacity pressure (4 GB budget) ==");
    let cfg = ClusterConfig {
        kv_budget: Some(fenghuang::units::Bytes::gb(4.0)),
        ..Default::default()
    };
    let mut cluster = Cluster::fh4(4, &model, cfg)?;
    let report = cluster.run(workload())?;
    println!("{}", report.summary());

    println!("== open-loop diurnal traffic: static 8 vs elastic 1–8 replicas ==");
    let tc = TrafficConfig {
        arrivals: ArrivalConfig {
            pattern: ArrivalPattern::Diurnal,
            qps: 12.0,
            ..Default::default()
        },
        mix: WorkloadMix::parse("chat+rag").expect("mix"),
        requests: 96,
        seed: 7,
        max_prompt: model.max_seq as usize,
        ..Default::default()
    };
    let mut stat = Cluster::fh4(8, &model, ClusterConfig::default())?;
    let rs = stat.run(traffic::generate(&tc)?)?;
    println!("-- static 8 --\n{}", rs.summary());
    let cfg = ClusterConfig {
        autoscale: Some(AutoscaleConfig { target_tokens: 8192, ..Default::default() }),
        ..Default::default()
    };
    let mut auto = Cluster::fh4(8, &model, cfg)?;
    let ra = auto.run(traffic::generate(&tc)?)?;
    println!("-- elastic --\n{}", ra.summary());
    println!(
        "elastic saving vs static: {:.1}% of replica-seconds at attainment {:.1}%",
        100.0 * (1.0 - ra.replica_seconds / rs.replica_seconds.max(1e-12)),
        100.0 * ra.fleet.slo_attainment(),
    );

    println!("== shared prefix-KV cache: agentic sessions, cache off vs on ==");
    // Multi-turn agentic traffic re-sends its growing conversation head
    // every turn; the shared cache in the TAB pool serves that prefix to
    // *any* replica, so prefill compute shrinks fleet-wide
    // (DESIGN.md §Prefix-Cache).
    let tc = TrafficConfig {
        mix: WorkloadMix::parse("agentic").expect("mix"),
        requests: 48,
        seed: 7,
        max_prompt: model.max_seq as usize,
        ..Default::default()
    };
    let mut plain = Cluster::fh4(4, &model, ClusterConfig::default())?;
    let rp = plain.run(traffic::generate(&tc)?)?;
    println!("-- cache off --\n{}", rp.summary());
    let cfg = ClusterConfig {
        prefix_cache: Some(PrefixCacheConfig::default()),
        ..Default::default()
    };
    let mut cached = Cluster::fh4(4, &model, cfg)?;
    let rc = cached.run(traffic::generate(&tc)?)?;
    println!("-- cache on --\n{}", rc.summary());
    println!(
        "prefix cache: {:.1}% of prefill tokens served from the pool | \
         makespan {:.3}s → {:.3}s",
        100.0 * rc.prefill_compute_saving(),
        rp.makespan().value(),
        rc.makespan().value(),
    );

    println!("== shared-fabric congestion: the same cached traffic, pool arbitrated ==");
    // Every run above charged the *unloaded* fabric latencies. Here the
    // TAB is a finite, arbitrated resource (DESIGN.md §Fabric-Contention):
    // a compressed burst of agentic traffic books its prefix fetches into
    // the shared pool's bandwidth ledger, and queueing delay appears in
    // TTFT — the question being whether the savings above survive N
    // replicas sharing one pool.
    let burst = TrafficConfig {
        arrivals: ArrivalConfig {
            pattern: ArrivalPattern::Replay,
            qps: 10_000.0,
            replay_gaps: vec![Seconds::us(100.0)],
            ..Default::default()
        },
        mix: WorkloadMix::parse("agentic").expect("mix"),
        requests: 96,
        seed: 7,
        max_prompt: model.max_seq as usize,
        ..Default::default()
    };
    for (label, mode, interleave) in [
        ("unloaded (off)", ContentionMode::Off, true),
        ("shared pool", ContentionMode::Shared, true),
        ("per-module, hashed", ContentionMode::PerModule, false),
    ] {
        let cfg = ClusterConfig {
            prefix_cache: Some(PrefixCacheConfig::default()),
            contention: ContentionConfig {
                mode,
                module_interleave: interleave,
                ..Default::default()
            },
            ..Default::default()
        };
        let mut cluster = Cluster::fh4(8, &model, cfg)?;
        let r = cluster.run(traffic::generate(&burst)?)?;
        match &r.fabric {
            Some(fr) => println!(
                "-- {label} --  p95 TTFT {:.1} ms | fetch stall {:.2} ms | {}",
                r.fleet.ttft.percentile_ms(95.0),
                r.fleet.prefix_fetch.as_ms(),
                fr.summary_line().trim_end(),
            ),
            None => println!(
                "-- {label} --  p95 TTFT {:.1} ms | fetch stall {:.2} ms | fabric unloaded",
                r.fleet.ttft.percentile_ms(95.0),
                r.fleet.prefix_fetch.as_ms(),
            ),
        }
    }
    Ok(())
}
