//! END-TO-END driver: real model, real compute, all three layers.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_e2e
//! ```
//!
//! 1. **Verify the TP-over-TAB pipeline**: four worker threads each run
//!    the `layer_shard_fwd` PJRT executable (L1 Pallas attention inside an
//!    L2 JAX block) and exchange partial sums through the functional TAB
//!    shared-memory pool via write-accumulate + completion notifications
//!    (§3.3.2 protocol). The sharded logits must match the single
//!    `model_fwd` executable.
//! 2. **Serve batched requests**: the continuous-batching scheduler
//!    drives the PJRT backend on the wall clock; reports TTFT / TPOT /
//!    throughput. Results are recorded in EXPERIMENTS.md.

use fenghuang::coordinator::tp::{verify_against_full_model, PjrtBackend, TpPipeline};
use fenghuang::coordinator::{Batcher, Request, Scheduler};
use fenghuang::runtime::artifacts::Bundle;
use fenghuang::units::Seconds;
use std::time::Instant;

fn main() -> fenghuang::Result<()> {
    let dir = Bundle::default_dir();
    if !dir.join("model_fwd.hlo.txt").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }

    // ---- Phase 1: TP-over-TAB numerics verification ----------------------
    println!("[1/2] bringing up 4 PJRT workers over the TAB pool…");
    let t0 = Instant::now();
    let mut tp = TpPipeline::new(&dir)?;
    let full = PjrtBackend::new(&dir)?;
    println!(
        "      workers up in {:.2}s (tp={}, model {} params)",
        t0.elapsed().as_secs_f64(),
        tp.meta.tp,
        tp.meta.param_count
    );

    let meta = tp.meta.clone();
    let tokens: Vec<Vec<i32>> = (0..meta.batch)
        .map(|b| (0..meta.seq).map(|s| ((b * 131 + s * 7) % meta.vocab) as i32).collect())
        .collect();
    let t0 = Instant::now();
    let max_diff = verify_against_full_model(&mut tp, &full, &tokens)?;
    let stats = tp.pool_stats();
    println!(
        "      sharded-vs-full max |Δlogit| = {max_diff:.2e}  ({} accumulates, {:.1} MB through TAB, {:.2}s)",
        stats.accumulates,
        stats.bytes_accumulated as f64 / 1e6,
        t0.elapsed().as_secs_f64()
    );
    assert!(max_diff < 1e-2, "TP pipeline diverged from the full model");
    println!("      ✅ communication-as-memory path verified end to end");
    drop(tp);

    // ---- Phase 2: serve batched requests over PJRT -----------------------
    println!("[2/2] serving 24 requests (batch ≤ {}, greedy gen)…", meta.batch);
    let backend = PjrtBackend::new(&dir)?;
    let batcher = Batcher::new(meta.batch, 64, meta.seq - 8);
    let mut sched = Scheduler::new(backend, batcher);
    let reqs: Vec<Request> = (0..24)
        .map(|id| Request {
            id,
            prompt: (0..40).map(|i| ((id as usize * 17 + i * 3) % meta.vocab) as i32).collect(),
            max_new_tokens: 8,
            arrival: Seconds::ZERO,
            ..Default::default()
        })
        .collect();
    sched.submit_all(reqs);
    let t0 = Instant::now();
    sched.run_to_completion()?;
    println!("      wall time {:.2}s\n{}", t0.elapsed().as_secs_f64(), sched.metrics.summary());
    let sample = &sched.responses[0];
    println!(
        "      sample response id={} tokens[last 8 generated]={:?}",
        sample.id,
        &sample.tokens[sample.tokens.len() - 8..]
    );
    println!("      ✅ end-to-end serving complete");
    Ok(())
}
