//! Quickstart: simulate the paper's headline comparison in ~30 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use fenghuang::prelude::*;
use fenghuang::sim::run_workload;
use fenghuang::units::Bandwidth;

fn main() -> Result<()> {
    let model = arch::gpt3_175b();
    let batch = 8;
    let (prompt, gen) = (4096, 1024); // the paper's Q&A task

    let base = run_workload(&baseline8(), &model, batch, prompt, gen)?;
    println!(
        "{:<11} TTFT {:>8.1} ms  TPOT {:>6.2} ms  E2E {:>6.2} s  GPUs 8",
        base.system,
        base.ttft.as_ms(),
        base.tpot.as_ms(),
        base.e2e.value()
    );

    for tbps in [4.0, 4.8, 5.6, 6.4] {
        let sys = fh4_15xm(Bandwidth::tbps(tbps));
        let r = run_workload(&sys, &model, batch, prompt, gen)?;
        println!(
            "{:<11} TTFT {:>8.1} ms  TPOT {:>6.2} ms  E2E {:>6.2} s  GPUs 4  @ {tbps} TB/s  local {:.1} GB",
            r.system,
            r.ttft.as_ms(),
            r.tpot.as_ms(),
            r.e2e.value(),
            r.peak_local.as_gb()
        );
    }
    println!("\nFengHuang serves the same workload with HALF the GPUs (paper: up to 50% GPU reduction).");
    Ok(())
}
