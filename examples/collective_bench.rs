//! Functional collectives shoot-out: TAB shared memory vs NVLink-style
//! ring — real data movement, verified numerics, measured throughput,
//! plus the analytic §3.3.3 table.
//!
//! ```bash
//! cargo run --release --example collective_bench
//! ```

use fenghuang::fabric::analysis::{allreduce_speedup_at, speedup, SpeedupConfig};
use fenghuang::fabric::collectives::{group, Collective};
use fenghuang::fabric::nvlink::run_ring;
use fenghuang::fabric::tab::TabPool;
use fenghuang::units::Bytes;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    // ---- functional equivalence + host throughput ------------------------
    let world = 4;
    let len = 1 << 20; // 4 MiB of f32 per rank
    println!("functional AllReduce, {world} ranks × {len} f32:");

    let t0 = Instant::now();
    let ring_out = run_ring(world, move |c| {
        let data: Vec<f32> = (0..len).map(|i| ((c.rank() + 1) * (i % 97)) as f32).collect();
        c.all_reduce(&data)
    });
    let ring_dt = t0.elapsed();

    let pool = Arc::new(TabPool::new(len * 2, 8, 4096));
    let comms = group(pool, world);
    let t0 = Instant::now();
    let handles: Vec<_> = comms
        .into_iter()
        .map(|mut c| {
            std::thread::spawn(move || {
                let data: Vec<f32> =
                    (0..len).map(|i| ((c.rank() + 1) * (i % 97)) as f32).collect();
                c.all_reduce(&data).unwrap()
            })
        })
        .collect();
    let tab_out: Vec<Vec<f32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let tab_dt = t0.elapsed();

    assert_eq!(ring_out[0], tab_out[0], "fabrics disagree!");
    let mb = (len * 4 * world) as f64 / 1e6;
    println!("  ring: {:>7.1} ms ({:.0} MB moved)  tab: {:>7.1} ms — identical results ✅",
        ring_dt.as_secs_f64() * 1e3, mb, tab_dt.as_secs_f64() * 1e3);

    // ---- analytic §3.3.3 table -------------------------------------------
    let cfg = SpeedupConfig::default();
    let r = speedup(&cfg);
    println!("\n§3.3.3 analytic speed-ups (N=8, 4.0 TB/s TAB vs 450 GB/s NVLink):");
    println!("  latency-bound  {:.0}×  (enablers {:.0} × 5)", r.overall_latency_bound, r.enabler1_latency);
    println!("  bandwidth-bound {:.2}× (enablers {:.2} × {:.2})",
        r.overall_bandwidth_bound, r.enabler1_bandwidth, r.enabler2_bandwidth);
    println!("\n  payload sweep (modelled AllReduce completion time ratio):");
    for kib in [2u64, 64, 2048, 65536, 1 << 21] {
        let s = allreduce_speedup_at(Bytes::kib(kib as f64), &cfg);
        println!("    {:>8} KiB  {s:>6.1}×", kib);
    }
    for op in [Collective::AllReduce, Collective::ReduceScatter, Collective::AllGather, Collective::AllToAll, Collective::P2p] {
        use fenghuang::fabric::collectives::tab_collective_time;
        use fenghuang::fabric::nvlink::ring_collective_time;
        let payload = Bytes::mib(64.0);
        let tab = tab_collective_time(op, payload, 8, cfg.tab_bw, &cfg.latencies);
        let ring = ring_collective_time(op, payload, 8, cfg.nvlink_bw, &cfg.latencies);
        println!("    {op:<14} 64 MiB: ring {:>9.1} µs vs tab {:>7.1} µs ({:.1}×)",
            ring.as_us(), tab.as_us(), ring / tab);
    }
}
