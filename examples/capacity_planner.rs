//! Capacity planner: which models fit which systems, and at what cost?
//!
//! Sweeps the paper's workloads across Baseline8 / FH4 presets and prints
//! the infrastructure view the paper's abstract argues from: local-memory
//! reduction, GPU-count reduction, and whether each deployment is even
//! feasible (does the working set fit?).
//!
//! ```bash
//! cargo run --release --example capacity_planner
//! ```

use fenghuang::models::{arch, memory};
use fenghuang::prelude::*;
use fenghuang::sim::run_workload;
use fenghuang::units::Bandwidth;

fn main() -> Result<()> {
    println!("model        weights(GB)  kv@8x5k(GB)  | baseline8 fit? | FH4 local need | GPU savings");
    for m in arch::eval_models() {
        let w = memory::param_bytes(&m);
        let kv = memory::kv_cache_bytes(&m, 8, 5120);
        // Baseline: per-GPU share of weights+KV must fit 141 GB.
        let per_gpu = (w + kv) / 8.0;
        let fits = per_gpu.as_gb() < 141.0;
        let fh = run_workload(&fh4_15xm(Bandwidth::tbps(4.8)), &m, 8, 4096, 1024)?;
        println!(
            "{:<12} {:>10.0} {:>12.0}  | {:<14} | {:>8.2} GB    | 8 → 4 GPUs ({:.0}% local-mem reduction)",
            m.name,
            w.as_gb(),
            kv.as_gb(),
            if fits { "yes" } else { "NO (shard!)" },
            fh.peak_local.as_gb(),
            (1.0 - fh.peak_local.as_gb() / 144.0) * 100.0,
        );
    }

    println!("\nremote-bandwidth sensitivity (GPT-3 E2E, Q&A):");
    let m = arch::gpt3_175b();
    let base = run_workload(&baseline8(), &m, 8, 4096, 1024)?;
    println!("  Baseline8          E2E {:>7.2} s", base.e2e.value());
    for tbps in [4.0, 4.4, 4.8, 5.2, 5.6, 6.0, 6.4] {
        for sys in [fh4_15xm(Bandwidth::tbps(tbps)), fh4_20xm(Bandwidth::tbps(tbps))] {
            let r = run_workload(&sys, &m, 8, 4096, 1024)?;
            println!(
                "  {:<10} @ {:.1} TB/s E2E {:>7.2} s ({:+.1}% vs baseline)",
                r.system,
                tbps,
                r.e2e.value(),
                (r.e2e / base.e2e - 1.0) * 100.0
            );
        }
    }
    Ok(())
}
