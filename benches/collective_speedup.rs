//! Bench target: §3.3.3 — FengHuang vs NVLink collective speed-ups
//! (70× latency-bound / 15.56× bandwidth-bound), the payload sweep, and
//! measured functional-collective throughput on the host.

mod common;

use fenghuang::fabric::collectives::group;
use fenghuang::fabric::nvlink::run_ring;
use fenghuang::fabric::tab::TabPool;
use std::sync::Arc;

fn main() {
    print!("{}", fenghuang::analysis::speedup_report());

    println!("functional collectives, host wall time (4 ranks × 1 MiB):");
    let len = 1 << 18;
    common::bench("ring.all_reduce 4x1MiB", 2, 10, || {
        run_ring(4, move |c| c.all_reduce(&vec![c.rank() as f32; len]))
    });
    common::bench("tab.all_reduce 4x1MiB", 2, 10, || {
        let pool = Arc::new(TabPool::new(len * 4, 8, 1024));
        let comms = group(pool, 4);
        let hs: Vec<_> = comms
            .into_iter()
            .map(|mut c| {
                std::thread::spawn(move || c.all_reduce(&vec![c.rank() as f32; len]).unwrap())
            })
            .collect();
        hs.into_iter().for_each(|h| {
            h.join().unwrap();
        });
    });
}
