//! Shared micro-benchmark harness.
//!
//! The offline build environment has no criterion crate, so `cargo bench`
//! targets are plain binaries (`harness = false`) using this warmup +
//! repeated-timing helper. Reported numbers: median and mean over
//! `iters` runs after `warmup` discarded runs.

// Each bench binary compiles its own copy of this module and uses a
// different subset of it.
#![allow(dead_code)]

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub iters: usize,
}

impl BenchResult {
    pub fn print(&self) {
        let (val, unit) = humanize(self.median_ns);
        let (mval, munit) = humanize(self.mean_ns);
        println!(
            "bench {:<44} median {val:>9.3} {unit:<2} mean {mval:>9.3} {munit:<2} ({} iters)",
            self.name, self.iters
        );
    }
}

fn humanize(ns: f64) -> (f64, &'static str) {
    if ns < 1e3 {
        (ns, "ns")
    } else if ns < 1e6 {
        (ns / 1e3, "µs")
    } else if ns < 1e9 {
        (ns / 1e6, "ms")
    } else {
        (ns / 1e9, "s")
    }
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench<R>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> R) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median_ns = samples[samples.len() / 2];
    let mean_ns = samples.iter().sum::<f64>() / samples.len() as f64;
    let r = BenchResult { name: name.to_string(), median_ns, mean_ns, iters };
    r.print();
    r
}

/// Throughput helper: bytes processed per wall second.
#[allow(dead_code)] // not every bench reports throughput
pub fn gbps(bytes: usize, median_ns: f64) -> f64 {
    bytes as f64 / median_ns * 1e9 / 1e9
}

/// Whether the bench was invoked with `--json`
/// (`cargo bench --bench X -- --json`; see scripts/bench_json.sh).
pub fn json_requested() -> bool {
    std::env::args().any(|a| a == "--json")
}

/// Whether the bench should run its tiny CI smoke sweep instead of the
/// full grid: `-- --smoke` or `FH_BENCH_SMOKE=1` (scripts/ci.sh). Heavy
/// benches shrink their sweeps; benches that are already cheap ignore it.
pub fn smoke() -> bool {
    std::env::args().any(|a| a == "--smoke") || std::env::var_os("FH_BENCH_SMOKE").is_some()
}

/// Write `BENCH_<name>.json` at the repo root — the perf-trajectory
/// artifact format (EXPERIMENTS.md §Capacity-Sweep).
pub fn write_bench_json(name: &str, body: &str) {
    let path = format!("{}/BENCH_{}.json", env!("CARGO_MANIFEST_DIR"), name);
    match std::fs::write(&path, body) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}

/// Standard envelope for row-oriented bench artifacts:
/// `{"bench": <name>, "rows": [<row>, …]}` where each row is an
/// already-serialised JSON object.
pub fn write_rows_json(name: &str, rows: &[String]) {
    let mut body = format!("{{\n  \"bench\": {},\n  \"rows\": [\n", json_str(name));
    for (i, row) in rows.iter().enumerate() {
        body.push_str("    ");
        body.push_str(row);
        body.push_str(if i + 1 == rows.len() { "\n" } else { ",\n" });
    }
    body.push_str("  ]\n}\n");
    write_bench_json(name, &body);
}

/// Minimal JSON escaping for the hand-rolled emitters (no serde in the
/// offline build).
pub fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}
