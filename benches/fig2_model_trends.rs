//! Bench target: regenerate the model-side Chapter-2 figures
//! (2.1 memory capacity, 2.2 MFU-vs-batch, 2.3 FLOPs/token, 2.4
//! compute/memory ratio, 2.6 byte-per-FLOP, 2.8 FLOPs per comm byte).
fn main() {
    print!("{}", fenghuang::analysis::fig2_model_trends());
}
