//! Bench target: local-capacity sweep of the active-tensor-paging
//! orchestrator (EXPERIMENTS.md §Capacity-Sweep — the Table 4.3
//! capacity-reduction curve).
//!
//! For each paper workload (GPT-3, Grok-1, QWEN3-235B) the sweep caps the
//! local paged-byte budget at 7%…100% of the per-GPU remote working set
//! and reports the steady-state decode step versus the full-residency
//! roofline, per eviction policy. Expected shape: the stall/capacity
//! trade-off is monotone, and at paper-band budgets (~10–20 GB) the
//! slowdown stays inside the paper's "performance maintained" envelope
//! while local capacity drops ≥ 90% vs the Baseline8 144 GB HBM.
//!
//! A second grid sweeps the 3-tier hierarchy (DESIGN.md §Tiering):
//! local budget × pool share × flash multiple, with the stable heat
//! bands homed on high-bandwidth flash. Expected shape: the smallest
//! feasible local budget shrinks monotonically as the flash tier grows
//! (flash room displaces permanently-HBM-homed bytes), and a flash tier
//! behind a roomy pool reproduces the 2-tier numbers bit for bit.
//!
//! `cargo bench --bench paging_sweep -- --json` additionally writes
//! `BENCH_paging_sweep.json` at the repo root (scripts/bench_json.sh).

mod common;

use fenghuang::config::{fh4_15xm, FlashConfig, DEFAULT_FLASH_TBPS};
use fenghuang::models::arch::{gpt3_175b, grok1, qwen3_235b};
use fenghuang::paging::{
    simulate_paged, NmcConfig, PagingConfig, PlacementPolicy, PolicyKind,
};
use fenghuang::trace::Phase;
use fenghuang::units::{Bandwidth, Bytes};

const FRACS: [f64; 8] = [0.07, 0.10, 0.15, 0.20, 0.30, 0.50, 0.75, 1.00];
const REFERENCE_HBM_GB: f64 = 144.0;

struct Row {
    model: String,
    policy: &'static str,
    budget_frac: f64,
    budget_gb: f64,
    steady_ms: f64,
    full_ms: f64,
    slowdown: f64,
    peak_gb: f64,
    reduction: f64,
    paged_gb: f64,
}

fn main() {
    // CI smoke mode (scripts/ci.sh): one model, three budget points.
    let smoke = common::smoke();
    let models: Vec<_> =
        if smoke { vec![gpt3_175b()] } else { vec![gpt3_175b(), grok1(), qwen3_235b()] };
    let fracs: &[f64] = if smoke { &[0.10, 0.50, 1.00] } else { &FRACS };
    let sys = fh4_15xm(Bandwidth::tbps(4.8));
    let phase = Phase::Decode { kv_len: 4608 };
    let batch = 8u64;
    let mut rows: Vec<Row> = Vec::new();

    println!("== paging sweep: steady decode step vs local budget (FH4-1.5xM @ 4.8 TB/s) ==");
    for model in models.clone() {
        // Full-residency roofline: uncapped LRU reaches zero-fetch steady
        // state after the first step.
        let full_cfg = PagingConfig {
            policy: PlacementPolicy { kind: PolicyKind::Lru, ..Default::default() },
            steps: 2,
            ..Default::default()
        };
        let full = simulate_paged(&sys, &model, batch, phase, &full_cfg).expect("full residency");
        let ws_gb = full.working_set.as_gb();
        println!(
            "\n{}: working set {ws_gb:.1} GB/GPU, full-residency step {:.3} ms",
            model.name,
            full.steady_step.as_ms()
        );
        println!(
            "{:<18} {:>6} {:>9} {:>11} {:>9} {:>9} {:>11}",
            "policy", "frac", "budget GB", "steady ms", "slowdown", "peak GB", "vs 144GB"
        );
        for kind in PolicyKind::all() {
            for &frac in fracs {
                let budget = Bytes::gb(ws_gb * frac);
                let cfg = PagingConfig {
                    local_budget: Some(budget),
                    policy: PlacementPolicy { kind, ..Default::default() },
                    steps: 2,
                    ..Default::default()
                };
                match simulate_paged(&sys, &model, batch, phase, &cfg) {
                    Ok(r) => {
                        let slowdown = r.steady_step / full.steady_step;
                        let reduction = r.capacity_reduction_vs(Bytes::gb(REFERENCE_HBM_GB));
                        println!(
                            "{:<18} {:>5.0}% {:>9.1} {:>11.3} {:>8.3}x {:>9.2} {:>10.1}%",
                            kind.name(),
                            frac * 100.0,
                            budget.as_gb(),
                            r.steady_step.as_ms(),
                            slowdown,
                            r.peak_local.as_gb(),
                            reduction * 100.0,
                        );
                        rows.push(Row {
                            model: model.name.clone(),
                            policy: kind.name(),
                            budget_frac: frac,
                            budget_gb: budget.as_gb(),
                            steady_ms: r.steady_step.as_ms(),
                            full_ms: full.steady_step.as_ms(),
                            slowdown,
                            peak_gb: r.peak_local.as_gb(),
                            reduction,
                            paged_gb: r.migration.bytes_in.as_gb(),
                        });
                    }
                    Err(e) => {
                        println!(
                            "{:<18} {:>5.0}% {:>9.1}   infeasible ({e})",
                            kind.name(),
                            frac * 100.0,
                            budget.as_gb(),
                        );
                    }
                }
            }
        }
    }

    // 3-tier flash grid: local budget × pool share × flash multiple,
    // all in units of the model's working set (minimal residency).
    let mut flash_rows: Vec<String> = Vec::new();
    let shares: &[f64] = if smoke { &[0.25] } else { &[0.25, 0.50] };
    let mults: &[f64] = if smoke { &[0.25, 1.00] } else { &[0.25, 0.50, 1.00] };
    let lfracs: &[f64] = if smoke { &[0.20, 0.50] } else { &[0.10, 0.20, 0.50] };
    println!(
        "\n== flash capacity grid (minimal residency, flash @ {DEFAULT_FLASH_TBPS} TB/s) =="
    );
    for model in models.clone() {
        let full_cfg = PagingConfig {
            policy: PlacementPolicy { kind: PolicyKind::Lru, ..Default::default() },
            steps: 2,
            ..Default::default()
        };
        let full = simulate_paged(&sys, &model, batch, phase, &full_cfg).expect("full residency");
        let ws_gb = full.working_set.as_gb();
        // Pin the 2-tier contract: a flash tier behind an uncapped pool
        // never receives a band, so every observable must match the
        // flash-less run bit for bit.
        let mut echo_sys = sys.clone();
        echo_sys.flash = Some(FlashConfig::gb(2.0 * ws_gb));
        let echo = simulate_paged(&echo_sys, &model, batch, phase, &full_cfg)
            .expect("flash echo");
        assert_eq!(echo.steady_step, full.steady_step, "{}: flash-off echo", model.name);
        assert_eq!(echo.cold_step, full.cold_step, "{}: flash-off echo", model.name);
        assert_eq!(echo.peak_local, full.peak_local, "{}: flash-off echo", model.name);
        assert_eq!(
            echo.migration.bytes_in, full.migration.bytes_in,
            "{}: flash-off echo",
            model.name
        );
        assert_eq!(echo.migration.flash_pages_in, 0, "{}: nothing may touch flash", model.name);

        println!(
            "\n{}: working set {ws_gb:.1} GB/GPU (pool share × flash multiple grid)",
            model.name
        );
        println!(
            "{:>6} {:>6} {:>6} {:>11} {:>9} {:>9} {:>9} {:>8}",
            "share", "flash", "local", "steady ms", "slowdown", "flash GB", "HBM GB", "peak GB"
        );
        for &share in shares {
            // The smallest feasible local budget can only shrink as the
            // flash tier grows: flash room displaces bytes that would
            // otherwise be permanently HBM-homed.
            let mut prev_min: Option<f64> = None;
            for &mult in mults {
                let mut fsys = sys.clone();
                fsys.flash = Some(FlashConfig {
                    capacity: Bytes::gb(ws_gb * mult),
                    bandwidth: Bandwidth::tbps(DEFAULT_FLASH_TBPS),
                });
                let mut min_feasible: Option<f64> = None;
                for &lf in lfracs {
                    let cfg = PagingConfig {
                        local_budget: Some(Bytes::gb(ws_gb * lf)),
                        pool_budget: Some(Bytes::gb(ws_gb * share)),
                        steps: 2,
                        ..Default::default()
                    };
                    match simulate_paged(&fsys, &model, batch, phase, &cfg) {
                        Ok(r) => {
                            min_feasible = min_feasible.or(Some(lf));
                            let slowdown = r.steady_step / full.steady_step;
                            println!(
                                "{:>5.0}% {:>5.0}% {:>5.0}% {:>11.3} {:>8.3}x {:>9.2} {:>9.2} {:>8.2}",
                                share * 100.0,
                                mult * 100.0,
                                lf * 100.0,
                                r.steady_step.as_ms(),
                                slowdown,
                                r.flash_homed.as_gb(),
                                r.local_homed.as_gb(),
                                r.peak_local.as_gb(),
                            );
                            flash_rows.push(format!(
                                "{{\"model\": {}, \"policy\": {}, \"budget_frac\": {lf}, \
                                 \"budget_gb\": {:.3}, \"pool_share\": {share}, \
                                 \"pool_gb\": {:.3}, \"flash_mult\": {mult}, \
                                 \"flash_gb\": {:.3}, \"flash_bw_tbps\": {DEFAULT_FLASH_TBPS}, \
                                 \"steady_ms\": {:.6}, \"full_ms\": {:.6}, \
                                 \"slowdown\": {:.4}, \"peak_gb\": {:.3}, \
                                 \"flash_homed_gb\": {:.3}, \"hbm_homed_gb\": {:.3}, \
                                 \"flash_paged_gb\": {:.3}}}",
                                common::json_str(&model.name),
                                common::json_str(PolicyKind::MinimalResidency.name()),
                                ws_gb * lf,
                                ws_gb * share,
                                ws_gb * mult,
                                r.steady_step.as_ms(),
                                full.steady_step.as_ms(),
                                r.steady_step / full.steady_step,
                                r.peak_local.as_gb(),
                                r.flash_homed.as_gb(),
                                r.local_homed.as_gb(),
                                r.migration.flash_bytes_in.as_gb(),
                            ));
                        }
                        Err(e) => println!(
                            "{:>5.0}% {:>5.0}% {:>5.0}%   infeasible ({e})",
                            share * 100.0,
                            mult * 100.0,
                            lf * 100.0,
                        ),
                    }
                }
                if let Some(p) = prev_min {
                    let c = min_feasible.unwrap_or_else(|| {
                        panic!(
                            "{} share {share}: feasibility regressed — flash ×{mult} \
                             serves no budget a smaller tier served",
                            model.name
                        )
                    });
                    assert!(
                        c <= p + 1e-12,
                        "{} share {share}: min feasible local frac rose {p} → {c} \
                         as flash grew to ×{mult}",
                        model.name
                    );
                }
                prev_min = min_feasible.or(prev_min);
            }
        }
    }

    // NMC ablation at the paper-band budget.
    println!("\n== NMC offload ablation (minimal residency, 15% budget) ==");
    for model in models {
        let full_cfg = PagingConfig {
            policy: PlacementPolicy { kind: PolicyKind::Lru, ..Default::default() },
            steps: 2,
            ..Default::default()
        };
        let full = simulate_paged(&sys, &model, batch, phase, &full_cfg).expect("full");
        let budget = Bytes::gb(full.working_set.as_gb() * 0.15);
        let mk = |nmc: bool| {
            let cfg = PagingConfig {
                local_budget: Some(budget),
                nmc: NmcConfig { enabled: nmc },
                steps: 2,
                ..Default::default()
            };
            simulate_paged(&sys, &model, batch, phase, &cfg)
        };
        match (mk(false), mk(true)) {
            (Ok(off), Ok(on)) => println!(
                "{:<10} off {:>9.3} ms | on {:>9.3} ms | {} ops in-pool",
                model.name,
                off.steady_step.as_ms(),
                on.steady_step.as_ms(),
                on.nmc_offloads,
            ),
            _ => println!("{:<10} infeasible at 15%", model.name),
        }
    }

    if common::json_requested() {
        let mut json_rows: Vec<String> = rows
            .iter()
            .map(|r| {
                format!(
                    "{{\"model\": {}, \"policy\": {}, \"budget_frac\": {}, \
                     \"budget_gb\": {:.3}, \"steady_ms\": {:.6}, \"full_ms\": {:.6}, \
                     \"slowdown\": {:.4}, \"peak_gb\": {:.3}, \
                     \"reference_hbm_gb\": {REFERENCE_HBM_GB}, \"reduction_vs_ref\": {:.4}, \
                     \"paged_gb\": {:.3}}}",
                    common::json_str(&r.model),
                    common::json_str(r.policy),
                    r.budget_frac,
                    r.budget_gb,
                    r.steady_ms,
                    r.full_ms,
                    r.slowdown,
                    r.peak_gb,
                    r.reduction,
                    r.paged_gb,
                )
            })
            .collect();
        json_rows.extend(flash_rows);
        common::write_rows_json("paging_sweep", &json_rows);
    }
}
