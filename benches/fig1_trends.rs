//! Bench target: regenerate Figure 1.1 (AI users + model-size trends).
fn main() {
    print!("{}", fenghuang::analysis::fig1_trends());
}
