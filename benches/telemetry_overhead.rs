//! Telemetry overhead gate (EXPERIMENTS.md §Telemetry-Overhead).
//!
//! Runs the perf-gate scenario (4 replicas × 2 000 diurnal chat
//! requests through the event core — the same shape as
//! `perf_hotpath`'s gate section) three ways:
//!
//! * **off** — `telemetry: None`, twice, asserting the runs are
//!   bit-identical (the strict-passthrough guarantee, from the bench's
//!   side of the fence);
//! * **on** — default 100 ms sampling, asserting every *count* matches
//!   the off run exactly and every recorded span conserves its TTFT
//!   bitwise;
//! * **timed** — median wall time of both; in full (non-smoke) mode the
//!   telemetry-on run must cost < 10 % over telemetry-off.
//!
//! `-- --json` writes BENCH_telemetry_overhead.json (scripts/bench_json.sh).

mod common;

use fenghuang::coordinator::{Cluster, ClusterConfig, ClusterReport, Request};
use fenghuang::models::arch::gpt3_175b;
use fenghuang::telemetry::TelemetryConfig;
use fenghuang::traffic::{self, ArrivalConfig, ArrivalPattern, TrafficConfig, WorkloadMix};

/// The perf-gate workload: same shape and seed as `perf_hotpath`'s gate
/// section so the overhead number rides a known scenario.
fn diurnal_chat(requests: usize, qps: f64) -> Vec<Request> {
    let tc = TrafficConfig {
        arrivals: ArrivalConfig {
            pattern: ArrivalPattern::Diurnal,
            qps,
            ..Default::default()
        },
        mix: WorkloadMix::parse("chat").expect("mix"),
        requests,
        seed: 7,
        max_prompt: gpt3_175b().max_seq as usize,
        slo: None,
    };
    traffic::generate(&tc).expect("workload")
}

fn run(cfg: &ClusterConfig, reqs: &[Request]) -> ClusterReport {
    let mut c = Cluster::fh4(4, &gpt3_175b(), cfg.clone()).expect("cluster");
    c.run(reqs.to_vec()).expect("run")
}

fn main() {
    let smoke = common::smoke();
    let reqs = diurnal_chat(2000, 40.0);
    let off_cfg = ClusterConfig::default();
    let on_cfg = ClusterConfig { telemetry: Some(TelemetryConfig::default()), ..Default::default() };

    // Correctness fence before any timing: off is bit-identical run to
    // run, on changes no count, and the spans conserve TTFT bitwise.
    let off = run(&off_cfg, &reqs);
    let off2 = run(&off_cfg, &reqs);
    assert!(off.telemetry.is_none(), "off run must publish no telemetry");
    assert_eq!(
        off.fleet.clock.to_bits(),
        off2.fleet.clock.to_bits(),
        "telemetry-off runs must be bit-identical"
    );
    assert_eq!(
        off.fleet.ttft.mean_ms().to_bits(),
        off2.fleet.ttft.mean_ms().to_bits(),
        "telemetry-off latency stats must be bit-identical"
    );
    let on = run(&on_cfg, &reqs);
    let tel = on.telemetry.as_ref().expect("telemetry report");
    assert_eq!(on.fleet.completed, off.fleet.completed, "completions must not shift");
    assert_eq!(on.fleet.tokens_generated, off.fleet.tokens_generated, "tokens must not shift");
    assert_eq!(on.fleet.shed, off.fleet.shed, "sheds must not shift");
    assert_eq!(on.fleet.rejected, off.fleet.rejected, "rejections must not shift");
    assert_eq!(
        on.fleet.ttft.mean_ms().to_bits(),
        off.fleet.ttft.mean_ms().to_bits(),
        "ttft must not shift under observation"
    );
    assert_eq!(tel.spans.len() as u64, on.fleet.completed, "one span per completion");
    for s in &tel.spans {
        assert!(s.conserves_ttft(), "span {} must conserve its measured TTFT", s.id);
    }
    assert!(!tel.samples.is_empty(), "gate run must produce samples");
    println!(
        "fence: {} completions, {} spans, {} samples — counts identical on/off\n",
        on.fleet.completed,
        tel.spans.len(),
        tel.samples.len()
    );

    // Timed comparison.
    let iters = if smoke { 3 } else { 7 };
    let r_off = common::bench("cluster.gate 4r x 2000 telemetry off", 1, iters, || {
        run(&off_cfg, &reqs).fleet.completed
    });
    let r_on = common::bench("cluster.gate 4r x 2000 telemetry on", 1, iters, || {
        run(&on_cfg, &reqs).fleet.completed
    });
    let overhead = r_on.median_ns / r_off.median_ns - 1.0;
    println!("\n  -> telemetry-on overhead {:+.2}% on the perf-gate scenario", overhead * 100.0);
    if !smoke {
        assert!(
            overhead < 0.10,
            "telemetry-on overhead must stay < 10% on the perf gate (got {:.1}%)",
            overhead * 100.0
        );
    }

    if common::json_requested() {
        common::write_rows_json(
            "telemetry_overhead",
            &[format!(
                "{{\"section\": \"gate\", \"replicas\": 4, \"requests\": 2000, \
                 \"off_ns\": {:.0}, \"on_ns\": {:.0}, \"overhead_frac\": {:.4}, \
                 \"spans\": {}, \"samples\": {}, \"smoke\": {smoke}}}",
                r_off.median_ns,
                r_on.median_ns,
                overhead,
                tel.spans.len(),
                tel.samples.len()
            )],
        );
    }
}
