//! Bench target: regenerate the hardware-side Chapter-2 figures
//! (2.5 FLOPS/GB, 2.7 byte-per-FLOP, 2.9 FLOPS-per-Gbps) and the
//! Chapter-5 bandwidth-per-capacity arithmetic.
fn main() {
    print!("{}", fenghuang::analysis::fig2_hw_trends());
    print!("{}", fenghuang::analysis::chapter5());
}
