//! Bench target: Table 3.1 — TAB operation latency model, plus measured
//! host-side latencies of the *functional* pool operations (the substrate
//! the serving example runs on).

mod common;

use fenghuang::fabric::tab::TabPool;
use fenghuang::units::{Bandwidth, Bytes};

fn main() {
    print!("{}", fenghuang::analysis::table31());

    println!("modelled op latency vs payload (Eqs 3.1–3.3 at 4.0 TB/s):");
    let lat = fenghuang::fabric::FabricLatencies::default();
    let bw = Bandwidth::tbps(4.0);
    for kib in [2.0, 64.0, 1024.0, 16384.0] {
        let b = Bytes::kib(kib);
        println!(
            "  {:>6.0} KiB  read {:>9.1} ns  write {:>9.1} ns  write-acc {:>9.1} ns",
            kib,
            lat.read_latency(b, bw).as_ns(),
            lat.write_latency(b, bw).as_ns(),
            lat.write_accumulate_latency(b, bw).as_ns()
        );
    }

    println!("\nfunctional TabPool host performance:");
    let pool = TabPool::new(1 << 24, 8, 1024);
    let region = pool.alloc(1 << 22).unwrap();
    let data = vec![1.0f32; 1 << 22]; // 16 MiB
    let bytes = data.len() * 4;
    let r = common::bench("tab.write 16MiB", 3, 30, || pool.write(region, 0, &data).unwrap());
    println!("  -> {:.2} GB/s", common::gbps(bytes, r.median_ns));
    let r = common::bench("tab.write_accumulate 16MiB", 3, 30, || {
        pool.write_accumulate(region, 0, &data).unwrap()
    });
    println!("  -> {:.2} GB/s", common::gbps(bytes, r.median_ns));
    let mut out = vec![0.0f32; 1 << 22];
    let r = common::bench("tab.read 16MiB", 3, 30, || pool.read_into(region, 0, &mut out).unwrap());
    println!("  -> {:.2} GB/s", common::gbps(bytes, r.median_ns));
    common::bench("tab.alloc+free 1MiB", 10, 1000, || {
        let r = pool.alloc(1 << 18).unwrap();
        pool.free(r);
    });
    common::bench("tab.notify+wait", 10, 1000, || {
        pool.notify("bench", 1);
        pool.wait_notifications("bench", 1);
        pool.reset_notifications("bench");
    });
}
