//! Bench target: fault-injection sweep (EXPERIMENTS.md §Fault-Sweep).
//!
//! The question this bench exists to ask: does the shared pool survive
//! operations? Every other experiment measures a healthy fleet; this one
//! injects the three fault classes (DESIGN.md §Faults) into a serving
//! run and reports the availability cost:
//!
//! * **passthrough** — an armed-but-empty schedule is bit-identical to
//!   no schedule at all (the fault machinery is free when unused);
//! * **crash/recovery** — a replica crash under SLO-carrying load,
//!   swept over repair times: the SLO-attainment dip is nonzero, the
//!   fleet recovers before the run ends, and recovery time is monotone
//!   in the repair time;
//! * **module blast radius** — a hottest-module kill under striped vs
//!   hashed extent placement: hashed concentration invalidates at least
//!   as many bytes as uniform striping (pigeonhole over chains);
//! * **link degradation** — a contention-budget squeeze makes the run
//!   strictly wait longer on the fabric, then budgets recover.
//!
//! SLO targets are self-calibrating: the crash cells set each request's
//! TTFT target to the healthy run's p95, so the pre-fault baseline sits
//! near 0.95 attainment whatever the hardware model says and the dip
//! measures the fault, not the calibration.
//!
//! `cargo bench --bench fault_sweep -- --json` writes
//! `BENCH_fault_sweep.json` (scripts/bench_json.sh `faults`);
//! `-- --smoke` (scripts/ci.sh) shrinks the sweep.

mod common;

use fenghuang::coordinator::{
    Cluster, ClusterConfig, ClusterReport, PoolPlacement, PrefixCacheConfig, Request, SloTarget,
};
use fenghuang::fabric::contention::{ContentionConfig, ContentionMode};
use fenghuang::faults::{FaultKind, FaultSchedule, FaultSpec, ModuleSel};
use fenghuang::models::arch::gpt3_175b;
use fenghuang::traffic::{self, ArrivalConfig, ArrivalPattern, TrafficConfig, WorkloadMix};
use fenghuang::units::Seconds;

const SEED: u64 = 13;
const REPLICAS: usize = 4;

/// Fixed-gap replay stream: deterministic arrivals, chat-mix lengths.
fn workload(requests: usize) -> Vec<Request> {
    let gap = Seconds::us(600.0);
    let tc = TrafficConfig {
        arrivals: ArrivalConfig {
            pattern: ArrivalPattern::Replay,
            qps: 1.0 / gap.value(),
            replay_gaps: vec![gap],
            ..Default::default()
        },
        mix: WorkloadMix::parse("chat").expect("mix"),
        requests,
        seed: SEED,
        max_prompt: gpt3_175b().max_seq as usize,
        slo: None,
    };
    traffic::generate(&tc).expect("workload")
}

fn run(cfg: ClusterConfig, reqs: Vec<Request>) -> ClusterReport {
    let mut cluster = Cluster::fh4(REPLICAS, &gpt3_175b(), cfg).expect("cluster");
    cluster.run(reqs).expect("run")
}

/// Uniform-chain session workload for the blast-radius cells: 16
/// sessions, every prompt of a session identical (`chain_len` tokens,
/// distinct first token per session), so each session is exactly one
/// trie chain of the same depth and the hottest-module comparison is a
/// pure chains-per-module pigeonhole.
fn uniform_sessions(requests: usize, chain_len: usize) -> Vec<Request> {
    let sessions = 16;
    let gap = Seconds::us(600.0);
    (0..requests)
        .map(|i| {
            let s = (i % sessions) as i32;
            Request {
                id: i as u64,
                prompt: (0..chain_len as i32).map(|t| s * 1024 + t + 1).collect(),
                max_new_tokens: 16,
                arrival: gap * i as f64,
                ..Default::default()
            }
        })
        .collect()
}

fn crash_schedule(at: Seconds, repair: Seconds, window: Seconds) -> FaultSchedule {
    FaultSchedule {
        events: vec![FaultSpec {
            at,
            kind: FaultKind::ReplicaCrash { replica: 1, repair },
        }],
        window,
        epsilon: 0.1,
    }
}

fn main() {
    let smoke = common::smoke();
    let mut json_rows: Vec<String> = Vec::new();
    let requests = if smoke { 48 } else { 96 };

    // ── Passthrough: an armed-but-empty schedule must not move a bit ──
    let featureful = || ClusterConfig {
        prefix_cache: Some(PrefixCacheConfig::default()),
        contention: ContentionConfig { mode: ContentionMode::Shared, ..Default::default() },
        ..Default::default()
    };
    let plain = run(featureful(), workload(requests));
    let armed = run(
        ClusterConfig { faults: Some(FaultSchedule::default()), ..featureful() },
        workload(requests),
    );
    for (label, a, b) in [
        ("makespan", plain.makespan().value(), armed.makespan().value()),
        ("ttft_p99", plain.fleet.ttft.percentile_ms(99.0), armed.fleet.ttft.percentile_ms(99.0)),
        ("fabric_wait", plain.fleet.fabric_wait.value(), armed.fleet.fabric_wait.value()),
        ("prefix_fetch", plain.fleet.prefix_fetch.value(), armed.fleet.prefix_fetch.value()),
    ] {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "empty fault schedule perturbed `{label}`: {a} vs {b}"
        );
    }
    println!("passthrough: empty schedule bit-identical to no schedule ✓\n");

    // ── Crash/recovery: repair-time sweep under self-calibrated SLOs ──
    let healthy = run(ClusterConfig::default(), workload(requests));
    assert_eq!(
        (healthy.fleet.completed + healthy.fleet.rejected + healthy.fleet.shed) as usize,
        requests
    );
    assert!(healthy.fleet.completed > 0, "calibration needs healthy completions");
    let slo = SloTarget {
        ttft: Seconds::ms(healthy.fleet.ttft.percentile_ms(95.0)),
        tpot: Seconds::ms(10_000.0),
    };
    let span = workload(requests).last().unwrap().arrival;
    let crash_at = span * 0.4;
    let window = span * 0.08;
    let repair_fracs: &[f64] = if smoke { &[0.05, 0.3] } else { &[0.05, 0.15, 0.3] };

    println!(
        "== crash/recovery sweep (gpt3, {REPLICAS} replicas, {requests} req, crash r1 @ {:.1} ms, \
         slo ttft = healthy p95 {:.2} ms, seed {SEED}) ==",
        crash_at.as_ms(),
        slo.ttft.as_ms()
    );
    println!("repair(ms)  dip%   recovery(ms)  requeued  lost-tok  goodput-lost  makespan(ms)");
    let mut prev_recovery = -1.0f64;
    for &frac in repair_fracs {
        let repair = span * frac;
        let mut reqs = workload(requests);
        for r in &mut reqs {
            r.slo = Some(slo);
        }
        let r = run(
            ClusterConfig {
                faults: Some(crash_schedule(crash_at, repair, window)),
                ..Default::default()
            },
            reqs,
        );
        let fr = r.faults.as_ref().expect("fault report");
        assert_eq!(fr.crashes, 1);
        assert_eq!(fr.rejoins, 1);
        assert!(fr.requests_requeued > 0, "a mid-run crash must evacuate work");
        assert_eq!(
            r.fleet.completed + r.fleet.rejected + r.fleet.shed,
            requests as u64,
            "conservation under crash"
        );
        // The availability story the subsystem exists to tell: the dip
        // is real, and the fleet climbs back out of it.
        assert!(
            fr.slo_dip > 0.0,
            "a replica crash under calibrated SLOs must dent attainment \
             (baseline {:.3}, dip {:.3})",
            fr.baseline_attainment,
            fr.dip_attainment
        );
        assert!(
            fr.recovered,
            "the fleet must recover before the run ends (repair {:.1} ms)",
            repair.as_ms()
        );
        let rec = fr.recovery_time.expect("recovered implies a recovery time").value();
        assert!(
            rec >= prev_recovery - 1e-9,
            "recovery time must be monotone in repair time: {:.4} s after {:.4} s",
            rec,
            prev_recovery
        );
        prev_recovery = rec;
        println!(
            "{:>10.1}  {:>4.1}  {:>12.1}  {:>8}  {:>8}  {:>12.0}  {:>12.1}",
            repair.as_ms(),
            100.0 * fr.slo_dip,
            rec * 1e3,
            fr.requests_requeued,
            fr.tokens_lost,
            fr.goodput_lost_tokens,
            r.makespan().as_ms(),
        );
        json_rows.push(format!(
            "{{\"section\": \"crash\", \"repair_ms\": {:.3}, \"slo_dip\": {:.6}, \
             \"baseline_attainment\": {:.6}, \"dip_attainment\": {:.6}, \
             \"recovery_ms\": {:.3}, \"recovered\": {}, \"requeued\": {}, \
             \"reprefilled\": {}, \"tokens_lost\": {}, \"goodput_lost\": {:.1}, \
             \"makespan_ms\": {:.3}}}",
            repair.as_ms(),
            fr.slo_dip,
            fr.baseline_attainment,
            fr.dip_attainment,
            rec * 1e3,
            fr.recovered,
            fr.requests_requeued,
            fr.requests_reprefilled,
            fr.tokens_lost,
            fr.goodput_lost_tokens,
            r.makespan().as_ms(),
        ));
    }

    // ── Module blast radius: striped vs hashed placement ──
    println!("\n== hottest-module kill, striped vs hashed chain placement ==");
    let chain_len = 128;
    let module_at = Seconds::us(600.0) * 20.0; // after all 16 chains exist
    let mut blast = Vec::new();
    for placement in [PoolPlacement::Striped, PoolPlacement::Hashed] {
        let r = run(
            ClusterConfig {
                prefix_cache: Some(PrefixCacheConfig {
                    modules: 8,
                    placement,
                    ..Default::default()
                }),
                faults: Some(FaultSchedule {
                    events: vec![FaultSpec {
                        at: module_at,
                        kind: FaultKind::ModuleFailure { module: ModuleSel::Hottest },
                    }],
                    ..Default::default()
                }),
                ..Default::default()
            },
            uniform_sessions(requests, chain_len),
        );
        let fr = r.faults.as_ref().expect("fault report");
        assert_eq!(fr.module_failures, 1);
        assert!(
            fr.extents_invalidated > 0,
            "{placement:?}: a hottest-module kill over 16 live chains must invalidate extents"
        );
        println!(
            "{placement:?}: invalidated {:.1} MB / {} extents, reprefilled {}",
            fr.bytes_invalidated.value() / 1e6,
            fr.extents_invalidated,
            fr.requests_reprefilled,
        );
        json_rows.push(format!(
            "{{\"section\": \"module\", \"placement\": {}, \"bytes_invalidated\": {:.1}, \
             \"extents_invalidated\": {}, \"reprefilled\": {}, \"makespan_ms\": {:.3}}}",
            common::json_str(&format!("{placement:?}")),
            fr.bytes_invalidated.value(),
            fr.extents_invalidated,
            fr.requests_reprefilled,
            r.makespan().as_ms(),
        ));
        blast.push(fr.extents_invalidated);
    }
    // 16 equal-depth chains into 8 modules: striping spreads exactly 2
    // per module, hashing collides to ≥ 2 by pigeonhole — the pooled
    // concentration risk the paper's shared TAB design accepts.
    assert!(
        blast[1] >= blast[0] && blast[0] > 0,
        "hashed blast radius {} must be ≥ striped {} (> 0)",
        blast[1],
        blast[0]
    );

    // ── Link degradation: squeezed budgets stretch fabric waits ──
    println!("\n== link degradation under shared arbitration ==");
    let base = run(featureful(), workload(requests));
    assert!(
        base.fleet.fabric_wait.value() > 0.0,
        "the contended baseline must queue on the fabric at all"
    );
    let deg = run(
        ClusterConfig {
            faults: Some(FaultSchedule {
                events: vec![FaultSpec {
                    at: Seconds::ZERO,
                    kind: FaultKind::LinkDegrade {
                        factor: 0.05,
                        duration: span * 2.0,
                    },
                }],
                ..Default::default()
            }),
            ..featureful()
        },
        workload(requests),
    );
    let fr = deg.faults.as_ref().expect("fault report");
    assert_eq!(fr.link_degrades, 1);
    assert!(
        deg.fleet.fabric_wait.value() > base.fleet.fabric_wait.value(),
        "a 20x budget squeeze must stretch fabric queueing: {:.4} ms vs {:.4} ms",
        deg.fleet.fabric_wait.as_ms(),
        base.fleet.fabric_wait.as_ms()
    );
    assert!(
        deg.makespan().value() >= base.makespan().value() - 1e-12,
        "degraded links cannot finish the run sooner"
    );
    println!(
        "fabric wait {:.3} ms → {:.3} ms, makespan {:.1} ms → {:.1} ms",
        base.fleet.fabric_wait.as_ms(),
        deg.fleet.fabric_wait.as_ms(),
        base.makespan().as_ms(),
        deg.makespan().as_ms(),
    );
    json_rows.push(format!(
        "{{\"section\": \"degrade\", \"factor\": 0.05, \"fabric_wait_base_ms\": {:.4}, \
         \"fabric_wait_degraded_ms\": {:.4}, \"makespan_base_ms\": {:.3}, \
         \"makespan_degraded_ms\": {:.3}}}",
        base.fleet.fabric_wait.as_ms(),
        deg.fleet.fabric_wait.as_ms(),
        base.makespan().as_ms(),
        deg.makespan().as_ms(),
    ));

    if common::json_requested() {
        common::write_rows_json("fault_sweep", &json_rows);
    }
}
