//! Bench target: Figure 4.1 + Table 4.3 — the paper's workload
//! evaluation: TTFT / TPOT / E2E for GPT-3, Grok-1, Qwen3 (+ Qwen3-R
//! reasoning) on Baseline8 vs FH4-1.5xM / FH4-2.0xM across the
//! 4.0–6.4 TB/s remote-bandwidth sweep, and the per-workload local-memory
//! peak.

mod common;

use fenghuang::config::{baseline8, fh4_15xm};
use fenghuang::models::arch::gpt3_175b;
use fenghuang::trace::Phase;
use fenghuang::units::Bandwidth;

fn main() {
    print!("{}", fenghuang::analysis::fig41_and_table43().expect("fig41"));

    println!("simulator cost (one full workload evaluation):");
    common::bench("sim.gpt3.baseline8.decode", 2, 20, || {
        fenghuang::sim::simulate(&baseline8(), &gpt3_175b(), 8, Phase::Decode { kv_len: 4608 })
            .unwrap()
    });
    common::bench("sim.gpt3.fh4.decode", 2, 20, || {
        fenghuang::sim::simulate(
            &fh4_15xm(Bandwidth::tbps(4.8)),
            &gpt3_175b(),
            8,
            Phase::Decode { kv_len: 4608 },
        )
        .unwrap()
    });
}
