//! Bench target: cluster-scale serving sweep (EXPERIMENTS.md §Serve-Scale).
//!
//! 1. Replica-count sweep 1→16 on the paper's three workloads: fleet
//!    throughput and makespan under a fixed saturating request stream.
//! 2. Policy shoot-out at 4 replicas on a heterogeneous stream:
//!    round-robin vs least-outstanding-tokens vs kv-affinity (load
//!    imbalance + tail TTFT).
//! 3. Aggregated 4 vs disaggregated 2:2 — KV handoff cost over the TAB
//!    fabric vs a shared-nothing link.

use fenghuang::coordinator::cluster::{session_workload, Cluster, ClusterConfig};
use fenghuang::coordinator::router::Policy;
use fenghuang::coordinator::Request;
use fenghuang::models::arch::{gpt3_175b, grok1, qwen3_235b};
use fenghuang::units::Seconds;

/// Saturating stream: arrivals much faster than service, so makespan is
/// capacity-bound and throughput reflects fleet width.
fn stream(n: usize) -> Vec<Request> {
    session_workload(n, 8, 1024, 32, Seconds::ms(1.0))
}

/// Alternating long/short prompts to stress routing balance.
fn lopsided(n: usize) -> Vec<Request> {
    let mut reqs = stream(n);
    for (i, r) in reqs.iter_mut().enumerate() {
        let len = if i % 2 == 0 { 3000 } else { 128 };
        r.prompt = vec![(i % 500) as i32 + 1; len];
    }
    reqs
}

fn main() {
    println!("== serve-scale: replica sweep (least-outstanding-tokens, 48 requests) ==");
    println!("model     replicas  makespan(s)  tok/s   p95 TTFT(ms)  mean util");
    for model in [gpt3_175b(), grok1(), qwen3_235b()] {
        let mut base_tps = 0.0;
        for replicas in [1usize, 2, 4, 8, 16] {
            let cfg = ClusterConfig { policy: Policy::LeastLoaded, ..Default::default() };
            let mut c = Cluster::fh4(replicas, &model, cfg).expect("cluster");
            let r = c.run(stream(48)).expect("run");
            let tps = r.throughput_tokens_per_s();
            if replicas == 1 {
                base_tps = tps;
            }
            let util: f64 = r.per_replica.iter().map(|p| p.utilization).sum::<f64>()
                / r.per_replica.len() as f64;
            println!(
                "{:<9} {:>8}  {:>10.2}  {:>6.0}  {:>11.1}  {:>8.2}  ({:.2}x vs 1 replica)",
                model.name,
                replicas,
                r.makespan().value(),
                tps,
                r.fleet.ttft.percentile_ms(95.0),
                util,
                if base_tps > 0.0 { tps / base_tps } else { 0.0 },
            );
        }
    }

    println!("\n== serve-scale: policy shoot-out (4 replicas, lopsided stream) ==");
    println!("policy                      imbalance  p95 TTFT(ms)  p99 TTFT(ms)  makespan(s)");
    for policy in [Policy::RoundRobin, Policy::LeastLoaded, Policy::KvAffinity] {
        let cfg = ClusterConfig { policy, ..Default::default() };
        let mut c = Cluster::fh4(4, &gpt3_175b(), cfg).expect("cluster");
        let r = c.run(lopsided(48)).expect("run");
        println!(
            "{:<26} {:>9.3}  {:>11.1}  {:>11.1}  {:>10.2}",
            policy.name(),
            r.imbalance,
            r.fleet.ttft.percentile_ms(95.0),
            r.fleet.ttft.percentile_ms(99.0),
            r.makespan().value(),
        );
    }

    println!("\n== serve-scale: aggregated 4 vs disaggregated 2:2 (gpt3) ==");
    for disagg in [None, Some((2usize, 2usize))] {
        let cfg = ClusterConfig {
            policy: Policy::LeastLoaded,
            max_batch: 8,
            disaggregate: disagg,
        };
        let mut c = Cluster::fh4(4, &gpt3_175b(), cfg).expect("cluster");
        let r = c.run(stream(48)).expect("run");
        let label = match disagg {
            None => "aggregated 4".to_string(),
            Some((p, d)) => format!("disaggregated {p}:{d}"),
        };
        println!(
            "{:<18} makespan {:>7.2}s  p95 TTFT {:>8.1} ms  p95 TPOT {:>7.2} ms  handoffs {} ({:.3} ms KV transfer)",
            label,
            r.makespan().value(),
            r.fleet.ttft.percentile_ms(95.0),
            r.fleet.tpot.percentile_ms(95.0),
            r.handoffs,
            r.handoff_time.as_ms(),
        );
    }
}
