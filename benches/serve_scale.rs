//! Bench target: cluster-scale serving sweep (EXPERIMENTS.md §Serve-Scale).
//!
//! 1. Replica-count sweep 1→64 on the paper's three workloads: fleet
//!    throughput and makespan under a fixed saturating request stream
//!    (the 32/64-replica points ride the event-driven cluster core,
//!    DESIGN.md §Event-Core — the stepping loop priced them out).
//! 2. Policy shoot-out at 4 replicas on a heterogeneous stream:
//!    round-robin vs least-outstanding-tokens vs kv-affinity (load
//!    imbalance + tail TTFT).
//! 3. Aggregated 4 vs disaggregated 2:2 — KV handoff cost over the TAB
//!    fabric vs a shared-nothing link.

mod common;

use fenghuang::coordinator::cluster::{session_workload, Cluster, ClusterConfig};
use fenghuang::coordinator::router::Policy;
use fenghuang::coordinator::Request;
use fenghuang::models::arch::{gpt3_175b, grok1, qwen3_235b};
use fenghuang::units::Seconds;

/// Saturating stream: arrivals much faster than service, so makespan is
/// capacity-bound and throughput reflects fleet width.
fn stream(n: usize) -> Vec<Request> {
    session_workload(n, 8, 1024, 32, Seconds::ms(1.0))
}

/// Alternating long/short prompts to stress routing balance.
fn lopsided(n: usize) -> Vec<Request> {
    let mut reqs = stream(n);
    for (i, r) in reqs.iter_mut().enumerate() {
        let len = if i % 2 == 0 { 3000 } else { 128 };
        r.prompt = vec![(i % 500) as i32 + 1; len];
    }
    reqs
}

fn main() {
    // CI smoke mode (scripts/ci.sh): tiny sweep, same code paths.
    let smoke = common::smoke();
    let n = if smoke { 16 } else { 256 };
    let replica_counts: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4, 8, 16, 32, 64] };
    let mut json_rows: Vec<String> = Vec::new();
    println!("== serve-scale: replica sweep (least-outstanding-tokens, {n} requests) ==");
    println!("model     replicas  makespan(s)  tok/s   p95 TTFT(ms)  mean util");
    for model in [gpt3_175b(), grok1(), qwen3_235b()] {
        let mut base_tps = 0.0;
        for &replicas in replica_counts {
            let cfg = ClusterConfig { policy: Policy::LeastLoaded, ..Default::default() };
            let mut c = Cluster::fh4(replicas, &model, cfg).expect("cluster");
            let r = c.run(stream(n)).expect("run");
            let tps = r.throughput_tokens_per_s();
            if replicas == 1 {
                base_tps = tps;
            }
            let util: f64 = r.per_replica.iter().map(|p| p.utilization).sum::<f64>()
                / r.per_replica.len() as f64;
            println!(
                "{:<9} {:>8}  {:>10.2}  {:>6.0}  {:>11.1}  {:>8.2}  ({:.2}x vs 1 replica)",
                model.name,
                replicas,
                r.makespan().value(),
                tps,
                r.fleet.ttft.percentile_ms(95.0),
                util,
                if base_tps > 0.0 { tps / base_tps } else { 0.0 },
            );
            json_rows.push(format!(
                "{{\"section\": \"replica_sweep\", \"model\": {}, \"replicas\": {replicas}, \
                 \"makespan_s\": {:.6}, \"tokens_per_s\": {:.3}, \"p95_ttft_ms\": {:.3}, \
                 \"p99_ttft_ms\": {:.3}, \"mean_util\": {:.4}}}",
                common::json_str(&model.name),
                r.makespan().value(),
                tps,
                r.fleet.ttft.percentile_ms(95.0),
                r.fleet.ttft.percentile_ms(99.0),
                util,
            ));
        }
    }

    println!("\n== serve-scale: policy shoot-out (4 replicas, lopsided stream) ==");
    println!("policy                      imbalance  p95 TTFT(ms)  p99 TTFT(ms)  makespan(s)");
    for policy in [Policy::RoundRobin, Policy::LeastLoaded, Policy::KvAffinity] {
        let cfg = ClusterConfig { policy, ..Default::default() };
        let mut c = Cluster::fh4(4, &gpt3_175b(), cfg).expect("cluster");
        let r = c.run(lopsided(n)).expect("run");
        println!(
            "{:<26} {:>9.3}  {:>11.1}  {:>11.1}  {:>10.2}",
            policy.name(),
            r.imbalance,
            r.fleet.ttft.percentile_ms(95.0),
            r.fleet.ttft.percentile_ms(99.0),
            r.makespan().value(),
        );
        json_rows.push(format!(
            "{{\"section\": \"policy_shootout\", \"policy\": {}, \"imbalance\": {:.4}, \
             \"p95_ttft_ms\": {:.3}, \"p99_ttft_ms\": {:.3}, \"makespan_s\": {:.6}}}",
            common::json_str(policy.name()),
            r.imbalance,
            r.fleet.ttft.percentile_ms(95.0),
            r.fleet.ttft.percentile_ms(99.0),
            r.makespan().value(),
        ));
    }

    println!("\n== serve-scale: aggregated 4 vs disaggregated 2:2 (gpt3) ==");
    for disagg in [None, Some((2usize, 2usize))] {
        let cfg = ClusterConfig {
            policy: Policy::LeastLoaded,
            max_batch: 8,
            disaggregate: disagg,
            ..Default::default()
        };
        let mut c = Cluster::fh4(4, &gpt3_175b(), cfg).expect("cluster");
        let r = c.run(stream(n)).expect("run");
        let label = match disagg {
            None => "aggregated 4".to_string(),
            Some((p, d)) => format!("disaggregated {p}:{d}"),
        };
        println!(
            "{:<18} makespan {:>7.2}s  p95 TTFT {:>8.1} ms  p95 TPOT {:>7.2} ms  handoffs {} ({:.3} ms KV transfer)",
            label,
            r.makespan().value(),
            r.fleet.ttft.percentile_ms(95.0),
            r.fleet.tpot.percentile_ms(95.0),
            r.handoffs,
            r.handoff_time.as_ms(),
        );
        json_rows.push(format!(
            "{{\"section\": \"disaggregation\", \"mode\": {}, \"makespan_s\": {:.6}, \
             \"p95_ttft_ms\": {:.3}, \"p95_tpot_ms\": {:.3}, \"handoffs\": {}, \
             \"handoff_ms\": {:.4}}}",
            common::json_str(&label),
            r.makespan().value(),
            r.fleet.ttft.percentile_ms(95.0),
            r.fleet.tpot.percentile_ms(95.0),
            r.handoffs,
            r.handoff_time.as_ms(),
        ));
    }

    println!("\n== serve-scale: per-replica KV budget sweep (2 replicas, gpt3) ==");
    println!("kv budget        makespan(s)  p99 TTFT(ms)  paging stall(ms)  peak spill(GB)");
    for budget_gb in [f64::INFINITY, 64.0, 16.0, 4.0] {
        let kv_budget =
            if budget_gb.is_finite() { Some(fenghuang::units::Bytes::gb(budget_gb)) } else { None };
        let cfg = ClusterConfig { kv_budget, ..Default::default() };
        let mut c = Cluster::fh4(2, &gpt3_175b(), cfg).expect("cluster");
        let r = c.run(stream(n.min(32))).expect("run");
        let label = if budget_gb.is_finite() {
            format!("{budget_gb:.0} GB")
        } else {
            "unlimited".to_string()
        };
        let p99 = r.fleet.ttft.percentile_ms(99.0);
        assert!(p99.is_finite(), "p99 TTFT must stay finite under KV pressure");
        println!(
            "{:<16} {:>11.2}  {:>12.1}  {:>16.3}  {:>13.2}",
            label,
            r.makespan().value(),
            p99,
            r.fleet.paging_stall.as_ms(),
            r.kv_spilled_peak.as_gb(),
        );
        json_rows.push(format!(
            "{{\"section\": \"kv_budget\", \"budget\": {}, \"makespan_s\": {:.6}, \
             \"p99_ttft_ms\": {:.3}, \"paging_stall_ms\": {:.4}, \"peak_spill_gb\": {:.3}}}",
            common::json_str(&label),
            r.makespan().value(),
            p99,
            r.fleet.paging_stall.as_ms(),
            r.kv_spilled_peak.as_gb(),
        ));
    }

    if common::json_requested() {
        common::write_rows_json("serve_scale", &json_rows);
    }
}
