//! Bench target: shared prefix-KV cache sweep
//! (EXPERIMENTS.md §Prefix-Cache).
//!
//! Mix × pool-share × paper-workload grid on a 4-replica FH4 fleet:
//! each cell serves the same seeded open-loop stream with the cache off
//! (the baseline — bit-identical to the pre-cache serving path) and on,
//! and reports hit rate, prefill tokens saved, the token-weighted
//! prefill-compute saving, and the measured makespan delta. A second
//! section ablates the NMC gather path (in-pool KV reads elide the
//! page-in, collapsing the fetch to the fixed command latency).
//!
//! `cargo bench --bench prefix_cache -- --json` writes
//! `BENCH_prefix_cache.json` at the repo root (scripts/bench_json.sh);
//! `-- --smoke` (scripts/ci.sh) shrinks the grid to a CI-sized run.

mod common;

use fenghuang::coordinator::{
    Cluster, ClusterConfig, ClusterReport, PrefixCacheConfig,
};
use fenghuang::models::arch::{gpt3_175b, grok1, qwen3_235b, ModelArch};
use fenghuang::traffic::{self, TrafficConfig, WorkloadMix};

const SEED: u64 = 7;
const REPLICAS: usize = 4;

fn traffic(model: &ModelArch, mix: &str, requests: usize) -> TrafficConfig {
    TrafficConfig {
        mix: WorkloadMix::parse(mix).expect("mix"),
        requests,
        seed: SEED,
        max_prompt: model.max_seq as usize,
        ..Default::default()
    }
}

fn run(model: &ModelArch, cfg: ClusterConfig, tc: &TrafficConfig) -> ClusterReport {
    let mut cluster = Cluster::fh4(REPLICAS, model, cfg).expect("cluster");
    cluster.run(traffic::generate(tc).expect("workload")).expect("run")
}

fn cached_cfg(pool_share: f64, nmc: bool) -> ClusterConfig {
    ClusterConfig {
        prefix_cache: Some(PrefixCacheConfig { pool_share, nmc_gather: nmc, ..Default::default() }),
        ..Default::default()
    }
}

fn main() {
    let smoke = common::smoke();
    let mut json_rows: Vec<String> = Vec::new();

    let models: Vec<ModelArch> = if smoke {
        vec![gpt3_175b()]
    } else {
        vec![gpt3_175b(), grok1(), qwen3_235b()]
    };
    // `agentic` is the reuse-heavy workload the cache is built for;
    // `chat+agentic` dilutes it with one-shot traffic; `chat+rag` is the
    // no-reuse control (every prefix unique → hit rate ≈ 0).
    let mixes: &[&str] =
        if smoke { &["agentic"] } else { &["agentic", "chat+agentic", "chat+rag"] };
    let shares: &[f64] = if smoke { &[0.05] } else { &[0.01, 0.05, 0.25] };
    let requests = if smoke { 12 } else { 48 };

    println!(
        "== prefix-cache sweep ({REPLICAS} replicas, {requests} requests, seed {SEED}) =="
    );
    println!(
        "model     mix            share  hit%   tok-hit%  saved-tok  compute-sav%  \
         makespan-sav%  pool-peak(GB)  evict"
    );
    for model in &models {
        for mix in mixes {
            let tc = traffic(model, mix, requests);
            // The no-cache baseline: the pre-cache serving path, run
            // twice to prove the off-configuration is bit-stable.
            let base = run(model, ClusterConfig::default(), &tc);
            let base2 = run(model, ClusterConfig::default(), &tc);
            assert_eq!(
                base.makespan(),
                base2.makespan(),
                "no-cache serving must be bit-identical across runs"
            );
            assert_eq!(base.fleet.prefill_tokens_saved, 0);
            for &share in shares {
                let r = run(model, cached_cfg(share, false), &tc);
                let pc = r.prefix_cache.expect("cache report");
                assert_eq!(
                    r.fleet.completed, base.fleet.completed,
                    "the cache must not lose requests"
                );
                if *mix == "agentic" && requests > 8 {
                    // > sessions requests of a pooled class: pigeonhole
                    // guarantees a repeated session, hence a hit.
                    assert!(pc.hits > 0, "agentic mix must hit the shared prefix");
                    assert!(r.fleet.prefill_tokens_saved > 0);
                }
                let makespan_saving =
                    1.0 - r.makespan().value() / base.makespan().value().max(1e-12);
                println!(
                    "{:<9} {:<14} {:>5.2} {:>5.1}  {:>8.1}  {:>9}  {:>12.1}  {:>13.1}  {:>13.3}  {:>5}",
                    model.name,
                    mix,
                    share,
                    100.0 * pc.hit_rate,
                    100.0 * pc.token_hit_rate,
                    r.fleet.prefill_tokens_saved,
                    100.0 * r.prefill_compute_saving(),
                    100.0 * makespan_saving,
                    pc.pool_bytes_peak.as_gb(),
                    pc.evicted_tokens,
                );
                json_rows.push(format!(
                    "{{\"section\": \"sweep\", \"model\": {}, \"mix\": {}, \
                     \"pool_share\": {share}, \"requests\": {requests}, \
                     \"hit_rate\": {:.6}, \"token_hit_rate\": {:.6}, \
                     \"prefill_tokens_saved\": {}, \"prefill_tokens\": {}, \
                     \"compute_saving_frac\": {:.6}, \"makespan_saving_frac\": {:.6}, \
                     \"base_makespan_s\": {:.9}, \"cached_makespan_s\": {:.9}, \
                     \"fetch_ms\": {:.6}, \"pool_peak_gb\": {:.6}, \
                     \"evicted_tokens\": {}, \"completed\": {}}}",
                    common::json_str(&model.name),
                    common::json_str(mix),
                    pc.hit_rate,
                    pc.token_hit_rate,
                    r.fleet.prefill_tokens_saved,
                    r.fleet.prefill_tokens,
                    r.prefill_compute_saving(),
                    makespan_saving,
                    base.makespan().value(),
                    r.makespan().value(),
                    r.fleet.prefix_fetch.as_ms(),
                    pc.pool_bytes_peak.as_gb(),
                    pc.evicted_tokens,
                    r.fleet.completed,
                ));
            }
            // Determinism of the cached path: repeat one share.
            let a = run(model, cached_cfg(shares[0], false), &tc);
            let b = run(model, cached_cfg(shares[0], false), &tc);
            assert_eq!(a.makespan(), b.makespan(), "cached serving must be deterministic");
            assert_eq!(a.fleet.prefill_tokens_saved, b.fleet.prefill_tokens_saved);
        }
    }

    // ---- NMC gather ablation --------------------------------------------
    // Same stream, same pool share; only the fetch path changes: staged
    // page-in (Eq 3.1 serialization) vs in-pool gather (fixed latency).
    println!("\n== NMC gather ablation (agentic, share 0.25) ==");
    for model in &models {
        let tc = traffic(model, "agentic", requests);
        let staged = run(model, cached_cfg(0.25, false), &tc);
        let gathered = run(model, cached_cfg(0.25, true), &tc);
        assert_eq!(
            staged.fleet.prefill_tokens_saved,
            gathered.fleet.prefill_tokens_saved,
            "the gather path changes fetch cost, not hit structure"
        );
        assert!(
            gathered.fleet.prefix_fetch <= staged.fleet.prefix_fetch,
            "eliding the page-in cannot cost more"
        );
        println!(
            "{:<9} staged fetch {:>9.3} ms | nmc-gather fetch {:>9.3} ms | saved tokens {}",
            model.name,
            staged.fleet.prefix_fetch.as_ms(),
            gathered.fleet.prefix_fetch.as_ms(),
            staged.fleet.prefill_tokens_saved,
        );
        json_rows.push(format!(
            "{{\"section\": \"nmc\", \"model\": {}, \"staged_fetch_ms\": {:.6}, \
             \"gather_fetch_ms\": {:.6}, \"prefill_tokens_saved\": {}}}",
            common::json_str(&model.name),
            staged.fleet.prefix_fetch.as_ms(),
            gathered.fleet.prefix_fetch.as_ms(),
            staged.fleet.prefill_tokens_saved,
        ));
    }

    if common::json_requested() {
        common::write_rows_json("prefix_cache", &json_rows);
    }
}
