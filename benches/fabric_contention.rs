//! Bench target: shared-fabric contention sweep
//! (EXPERIMENTS.md §Contention-Sweep).
//!
//! The question this bench exists to ask: do the savings every other
//! experiment measures survive N replicas hammering one shared TAB pool?
//! It sweeps replicas × mix × arbitration mode over a fixed-span
//! replay-arrival stream (gap = 0.6 ms / N, so fleet size scales offered
//! load against the fixed pool aggregate) with the shared prefix cache
//! driving real fabric bytes, and reports:
//!
//! * fabric busy fraction and queueing-delay percentiles per cell —
//!   the acceptance trend: both rise monotonically with replica count;
//! * per-module byte imbalance for interleaved vs hashed placement;
//! * the FH-vs-baseline communication speedup band: the same booked
//!   transfers priced over a shared-nothing NVLink link (unloaded) vs
//!   the contended TAB — EXPERIMENTS.md maps the band against the
//!   paper's 16x–70x figure.
//!
//! `cargo bench --bench fabric_contention -- --json` writes
//! `BENCH_fabric_contention.json` (scripts/bench_json.sh `contention`);
//! `-- --smoke` (scripts/ci.sh) shrinks the sweep.

mod common;

use fenghuang::config::baseline8;
use fenghuang::coordinator::{Cluster, ClusterConfig, ClusterReport, PrefixCacheConfig};
use fenghuang::fabric::contention::{ContentionConfig, ContentionMode, FabricReport};
use fenghuang::fabric::FabricLatencies;
use fenghuang::models::arch::gpt3_175b;
use fenghuang::traffic::{self, ArrivalConfig, ArrivalPattern, TrafficConfig, WorkloadMix};
use fenghuang::units::Seconds;

const SEED: u64 = 7;

/// Arbitration modes swept, keyed by label.
fn contention_for(label: &str) -> ContentionConfig {
    match label {
        "off" => ContentionConfig::default(),
        "shared" => ContentionConfig { mode: ContentionMode::Shared, ..Default::default() },
        "per-module" => {
            ContentionConfig { mode: ContentionMode::PerModule, ..Default::default() }
        }
        "per-module-hashed" => ContentionConfig {
            mode: ContentionMode::PerModule,
            module_interleave: false,
            ..Default::default()
        },
        other => panic!("unknown contention label {other}"),
    }
}

/// Fixed-span deterministic stream: `requests` arrivals at a constant
/// gap of 0.6 ms / replicas, so the offered fabric load scales with the
/// fleet while the wall span stays put — the cleanest monotone axis.
fn workload(mix: &str, replicas: usize, requests: usize) -> TrafficConfig {
    let gap = Seconds::us(600.0 / replicas as f64);
    TrafficConfig {
        arrivals: ArrivalConfig {
            pattern: ArrivalPattern::Replay,
            qps: 1.0 / gap.value(),
            replay_gaps: vec![gap],
            ..Default::default()
        },
        mix: WorkloadMix::parse(mix).expect("mix"),
        requests,
        seed: SEED,
        max_prompt: gpt3_175b().max_seq as usize,
        slo: None,
    }
}

fn run(replicas: usize, mix: &str, requests: usize, contention: ContentionConfig) -> ClusterReport {
    let cfg = ClusterConfig {
        prefix_cache: Some(PrefixCacheConfig::default()),
        contention,
        ..Default::default()
    };
    let mut cluster = Cluster::fh4(replicas, &gpt3_175b(), cfg).expect("cluster");
    let reqs = traffic::generate(&workload(mix, replicas, requests)).expect("workload");
    cluster.run(reqs).expect("run")
}

/// Communication cost of the booked transfer set on the contended TAB:
/// per-transfer command latency + serialization + queueing.
fn fh_comm(fr: &FabricReport, lat: &FabricLatencies) -> Seconds {
    lat.tab_read * fr.transfers as f64 + fr.serialization + fr.queue_total
}

/// The same transfer set priced over the shared-nothing baseline link,
/// unloaded: NVLink read+write commands plus raw serialization at the
/// Baseline8 450 GB/s per-direction link.
fn baseline_comm(fr: &FabricReport, lat: &FabricLatencies) -> Seconds {
    (lat.nvlink_read + lat.nvlink_write) * fr.transfers as f64
        + fr.bytes.over(baseline8().fabric_bw)
}

fn main() {
    let smoke = common::smoke();
    let mut json_rows: Vec<String> = Vec::new();

    let replica_sweep: &[usize] = if smoke { &[2, 4] } else { &[2, 4, 8, 12] };
    let mixes: &[&str] = if smoke { &["agentic"] } else { &["agentic", "chat+agentic"] };
    let per_replica_requests = if smoke { 24 } else { 48 };
    let modes = ["off", "shared", "per-module", "per-module-hashed"];
    let lat = FabricLatencies::default();

    // Unloaded-baseline identity: an Off ledger with deliberately weird
    // knobs must not perturb a single bit of the default run.
    let plain = run(2, mixes[0], per_replica_requests * 2, ContentionConfig::default());
    let weird_off = ContentionConfig {
        mode: ContentionMode::Off,
        ports: 7,
        modules: 3,
        window: Seconds::ns(1.0),
        module_interleave: false,
    };
    let off = run(2, mixes[0], per_replica_requests * 2, weird_off);
    assert_eq!(plain.makespan(), off.makespan(), "Off mode must be bit-identical");
    assert_eq!(plain.fleet.prefix_fetch, off.fleet.prefix_fetch);
    assert_eq!(
        plain.fleet.ttft.percentile_ms(95.0),
        off.fleet.ttft.percentile_ms(95.0)
    );
    assert!(off.fabric.is_none());
    println!("off-mode identity: bit-identical to the unloaded baseline ✓\n");

    println!(
        "== fabric-contention sweep (gpt3, {} req/replica, fixed {:.1} ms offered span, seed {SEED}) ==",
        per_replica_requests,
        per_replica_requests as f64 * 0.6
    );
    println!(
        "mix            mode               repl  busy%   q-p50(ms)  q-p95(ms)  q-p99(ms)  imbal  hotspot  booked(GB)  fetch(ms)  speedup"
    );

    let mut band: Option<(f64, f64)> = None;
    for mix in mixes {
        for mode in modes {
            let mut prev_busy = -1.0f64;
            let mut series: Vec<(usize, f64, f64, f64)> = Vec::new();
            for &n in replica_sweep {
                let r = run(n, mix, per_replica_requests * n, contention_for(mode));
                assert_eq!(r.fleet.completed as usize, per_replica_requests * n);
                let Some(fr) = r.fabric.clone() else {
                    // Unloaded baseline row: report the unloaded fetch cost.
                    println!(
                        "{:<14} {:<18} {:>4}  {:>5}  {:>9}  {:>9}  {:>9}  {:>5}  {:>7}  {:>10}  {:>9.2}  {:>7}",
                        mix, mode, n, "—", "—", "—", "—", "—", "—", "—",
                        r.fleet.prefix_fetch.as_ms(),
                        "—",
                    );
                    json_rows.push(format!(
                        "{{\"section\": \"sweep\", \"mix\": {}, \"mode\": {}, \"replicas\": {}, \
                         \"fetch_ms\": {:.4}, \"makespan_s\": {:.6}, \"p95_ttft_ms\": {:.3}}}",
                        common::json_str(mix),
                        common::json_str(mode),
                        n,
                        r.fleet.prefix_fetch.as_ms(),
                        r.makespan().value(),
                        r.fleet.ttft.percentile_ms(95.0),
                    ));
                    continue;
                };
                assert!(fr.transfers > 0, "prefix traffic must book transfers");
                let fh = fh_comm(&fr, &lat);
                let base = baseline_comm(&fr, &lat);
                let speedup = base.value() / fh.value().max(1e-300);
                band = Some(match band {
                    None => (speedup, speedup),
                    Some((lo, hi)) => (lo.min(speedup), hi.max(speedup)),
                });
                println!(
                    "{:<14} {:<18} {:>4}  {:>5.1}  {:>9.3}  {:>9.3}  {:>9.3}  {:>5.2}  {:>7}  {:>10.1}  {:>9.2}  {:>6.1}x",
                    mix,
                    mode,
                    n,
                    100.0 * fr.busy_frac,
                    fr.queue_p50.as_ms(),
                    fr.queue_p95.as_ms(),
                    fr.queue_p99.as_ms(),
                    fr.module_imbalance,
                    fr.hotspot_module,
                    fr.bytes.as_gb(),
                    r.fleet.prefix_fetch.as_ms(),
                    speedup,
                );
                json_rows.push(format!(
                    "{{\"section\": \"sweep\", \"mix\": {}, \"mode\": {}, \"replicas\": {}, \
                     \"busy_frac\": {:.6}, \"queue_p50_ms\": {:.4}, \"queue_p95_ms\": {:.4}, \
                     \"queue_p99_ms\": {:.4}, \"queue_total_ms\": {:.4}, \"imbalance\": {:.4}, \
                     \"hotspot\": {}, \"bytes_gb\": {:.3}, \"transfers\": {}, \
                     \"fabric_wait_ms\": {:.4}, \"fetch_ms\": {:.4}, \"makespan_s\": {:.6}, \
                     \"p95_ttft_ms\": {:.3}, \"fh_comm_ms\": {:.4}, \"baseline_comm_ms\": {:.4}, \
                     \"speedup\": {:.3}}}",
                    common::json_str(mix),
                    common::json_str(mode),
                    n,
                    fr.busy_frac,
                    fr.queue_p50.as_ms(),
                    fr.queue_p95.as_ms(),
                    fr.queue_p99.as_ms(),
                    fr.queue_total.as_ms(),
                    fr.module_imbalance,
                    fr.hotspot_module,
                    fr.bytes.as_gb(),
                    fr.transfers,
                    r.fleet.fabric_wait.as_ms(),
                    r.fleet.prefix_fetch.as_ms(),
                    r.makespan().value(),
                    r.fleet.ttft.percentile_ms(95.0),
                    fh.as_ms(),
                    base.as_ms(),
                    speedup,
                ));
                // Acceptance trend: more replicas on the same pool can
                // only busy it more.
                assert!(
                    fr.busy_frac >= prev_busy - 1e-12,
                    "busy fraction regressed at {mix}/{mode}/{n}: {} after {}",
                    fr.busy_frac,
                    prev_busy
                );
                prev_busy = fr.busy_frac;
                series.push((n, fr.busy_frac, fr.queue_p99.as_ms(), fr.queue_total.as_ms()));
            }
            if series.len() >= 2 {
                let first = series.first().unwrap();
                let last = series.last().unwrap();
                assert!(
                    last.1 > first.1,
                    "{mix}/{mode}: busy fraction must grow across the replica sweep \
                     ({:.4} → {:.4})",
                    first.1,
                    last.1
                );
                assert!(
                    last.2 >= first.2 - 1e-9,
                    "{mix}/{mode}: p99 queueing must not shrink with replicas \
                     ({:.4} → {:.4} ms)",
                    first.2,
                    last.2
                );
                assert!(
                    last.3 >= first.3 - 1e-9,
                    "{mix}/{mode}: total queueing must not shrink with replicas"
                );
            }
        }
        // Hashed whole-transfer placement must skew at least as hard as
        // uniform striping at the same scale (same cell, max replicas).
        let n = *replica_sweep.last().unwrap();
        let striped = run(n, mix, per_replica_requests * n, contention_for("per-module"));
        let hashed =
            run(n, mix, per_replica_requests * n, contention_for("per-module-hashed"));
        let si = striped.fabric.as_ref().unwrap().module_imbalance;
        let hi = hashed.fabric.as_ref().unwrap().module_imbalance;
        assert!(
            hi >= si - 1e-9,
            "{mix}: hashed imbalance {hi:.4} below striped {si:.4}"
        );
        println!("  → {mix}: module imbalance striped {si:.3} vs hashed {hi:.3}");
    }

    let (lo, hi) = band.expect("contended cells must produce a speedup band");
    assert!(lo.is_finite() && hi.is_finite() && lo > 0.0);
    println!(
        "\ncommunication speedup band vs shared-nothing baseline: {lo:.1}x – {hi:.1}x \
         (paper's bulk-bandwidth ceiling ≈ {:.1}x; its 16x–70x figure is the \
         small-message latency domain — see EXPERIMENTS.md §Contention-Sweep)",
        fenghuang::config::fh4_15xm(fenghuang::units::Bandwidth::tbps(
            fenghuang::config::DEFAULT_REMOTE_TBPS
        ))
        .fabric_bw
        .value()
            / baseline8().fabric_bw.value(),
    );
    json_rows.push(format!(
        "{{\"section\": \"band\", \"speedup_lo\": {lo:.3}, \"speedup_hi\": {hi:.3}}}"
    ));

    if common::json_requested() {
        common::write_rows_json("fabric_contention", &json_rows);
    }
}
