//! Ablation benches for the design choices DESIGN.md §5 calls out:
//!
//! 1. prefetch lookahead window w (paper: lookahead-1 at layer-node
//!    granularity ≈ w=10 at our op granularity);
//! 2. baseline framework-overhead calibration knob;
//! 3. KV paging policy (direct SM-from-remote vs staged through local);
//! 4. Eq 4.1 link-efficiency curve (on vs ideal line rate);
//! 5. TAB striping granularity (functional pool throughput).

use fenghuang::config::{baseline8, fh4_15xm};
use fenghuang::fabric::tab::TabPool;
use fenghuang::models::arch::{gpt3_175b, grok1};
use fenghuang::sim::{simulate, simulate_with_policy, PrefetchPolicy};
use fenghuang::trace::Phase;
use fenghuang::units::Bandwidth;

fn main() {
    let fh = fh4_15xm(Bandwidth::tbps(4.8));
    let decode = Phase::Decode { kv_len: 4608 };

    println!("== Ablation 1: prefetch lookahead window (Grok-1 decode, FH4@4.8) ==");
    println!("window  TPOT(ms)  exposed(ms)  peak_local(GB)");
    for w in [1usize, 2, 4, 6, 10, 16, 32] {
        let p = PrefetchPolicy { window: w, ..Default::default() };
        let r = simulate_with_policy(&fh, &grok1(), 8, decode, &p).unwrap();
        println!(
            "{w:>6}  {:>8.2}  {:>11.2}  {:>8.2}",
            r.total.as_ms(),
            r.exposed_prefetch.as_ms(),
            r.peak_local.as_gb()
        );
    }

    println!("\n== Ablation 2: baseline framework-overhead knob (GPT-3 TTFT) ==");
    println!("overhead  base TTFT(s)  FH TTFT(s)  FH advantage");
    let fh_r = simulate(&fh, &gpt3_175b(), 8, Phase::Prefill { prompt_len: 4096 }).unwrap();
    for ov in [1.0, 1.2, 1.4, 1.55, 1.7, 1.9] {
        let mut base = baseline8();
        base.framework_overhead = ov;
        let b = simulate(&base, &gpt3_175b(), 8, Phase::Prefill { prompt_len: 4096 }).unwrap();
        println!(
            "{ov:>8.2}  {:>11.2}  {:>10.2}  {:>+9.1}%",
            b.total.value(),
            fh_r.total.value(),
            (1.0 - fh_r.total / b.total) * 100.0
        );
    }

    println!("\n== Ablation 3: KV path — direct-from-remote vs paged-through-local ==");
    for (label, page_kv) in [("direct (default)", false), ("paged", true)] {
        let p = PrefetchPolicy { page_kv, ..Default::default() };
        let r = simulate_with_policy(&fh, &gpt3_175b(), 8, decode, &p).unwrap();
        println!(
            "{label:<18} TPOT {:>7.2} ms  peak local {:>6.2} GB  paging busy {:>7.2} ms",
            r.total.as_ms(),
            r.peak_local.as_gb(),
            r.paging_busy.as_ms()
        );
    }

    println!("\n== Ablation 4: Eq 4.1 efficiency curve vs ideal link ==");
    use fenghuang::models::mfu::{link_eff, transfer_time};
    use fenghuang::units::Bytes;
    let bw = Bandwidth::tbps(4.0);
    println!("tensor      eff     modelled(µs)  ideal(µs)  penalty");
    for mib in [0.25, 1.0, 16.0, 256.0, 1024.0] {
        let b = Bytes::mib(mib);
        let t = transfer_time(b, bw);
        let ideal = b.over(bw);
        println!(
            "{:>7.2}MiB {:>6.3} {:>12.2} {:>10.2} {:>8.2}×",
            mib,
            link_eff(b, bw),
            t.as_us(),
            ideal.as_us(),
            t / ideal
        );
    }

    println!("\n== Ablation 5: TAB striping granularity (functional pool, 16 MiB writes) ==");
    let data = vec![1.0f32; 1 << 22];
    for granule in [64usize, 256, 1024, 4096, 16384] {
        let pool = TabPool::new(1 << 23, 8, granule);
        let region = pool.alloc(1 << 22).unwrap();
        let t0 = std::time::Instant::now();
        for _ in 0..10 {
            pool.write_accumulate(region, 0, &data).unwrap();
        }
        let dt = t0.elapsed().as_secs_f64() / 10.0;
        println!(
            "granule {granule:>6} elems: {:>7.2} GB/s accumulate",
            (data.len() * 4) as f64 / dt / 1e9
        );
    }
}
