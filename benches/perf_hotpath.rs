//! Hot-path benchmarks (§Perf of EXPERIMENTS.md).
//!
//! L3 targets: trace generation, DES scheduling, whole-simulation
//! latency, serving-loop throughput, TAB accumulate bandwidth — plus the
//! cluster-core sections added with the event calendar
//! (DESIGN.md §Event-Core):
//!
//! * `gate` — a fixed 4-replica × 2 000-request diurnal run through the
//!   event core, always at this size so scripts/ci.sh can compare the
//!   fresh number against the committed baseline and fail on a > 2×
//!   regression;
//! * `event_vs_stepping` — the same workload through the stepping
//!   oracle (`run_stepping`) and the event core (`run`); in full mode
//!   (16 replicas × 100 000 requests) the event core must win by ≥ 10×;
//! * `scale` — the event core alone at fleet scale (full mode:
//!   64 replicas × 1 000 000 lean requests), which the stepping loop
//!   cannot reach in bench-able time.
//!
//! Run before and after each optimization; the iteration log lives in
//! EXPERIMENTS.md. `-- --json` writes BENCH_perf_hotpath.json;
//! `-- --smoke` (scripts/ci.sh) shrinks the comparison/scale sections.

mod common;

use fenghuang::config::{baseline8, fh4_15xm};
use fenghuang::coordinator::{
    synthetic_workload, Batcher, Cluster, ClusterConfig, Request, Scheduler, SimBackend,
};
use fenghuang::fabric::tab::TabPool;
use fenghuang::models::arch::{gpt3_175b, qwen3_235b};
use fenghuang::sim::{simulate_trace, PrefetchPolicy};
use fenghuang::trace::{generate, Phase, TraceConfig};
use fenghuang::traffic::{self, ArrivalConfig, ArrivalPattern, TrafficConfig, WorkloadMix};
use fenghuang::units::{Bandwidth, Seconds};
use std::sync::Arc;
use std::time::Instant;

/// Diurnal chat stream, the workload shape of the cluster sections.
/// Same seed at every size so gate runs are comparable across commits.
fn diurnal_chat(requests: usize, qps: f64) -> Vec<Request> {
    let tc = TrafficConfig {
        arrivals: ArrivalConfig {
            pattern: ArrivalPattern::Diurnal,
            qps,
            ..Default::default()
        },
        mix: WorkloadMix::parse("chat").expect("mix"),
        requests,
        seed: 7,
        max_prompt: gpt3_175b().max_seq as usize,
        slo: None,
    };
    traffic::generate(&tc).expect("workload")
}

fn main() {
    let smoke = common::smoke();
    let mut json_rows: Vec<String> = Vec::new();
    let fh = fh4_15xm(Bandwidth::tbps(4.8));

    // Trace generation (per simulation).
    common::bench("trace.generate gpt3 decode", 3, 50, || {
        generate(&TraceConfig {
            model: gpt3_175b(),
            tp: 4,
            batch: 8,
            phase: Phase::Decode { kv_len: 4608 },
        })
    });
    common::bench("trace.generate qwen3 decode (846 ops)", 3, 50, || {
        generate(&TraceConfig {
            model: qwen3_235b(),
            tp: 4,
            batch: 8,
            phase: Phase::Decode { kv_len: 4608 },
        })
    });

    // Pure scheduling over a pre-built trace.
    let tr = generate(&TraceConfig {
        model: qwen3_235b(),
        tp: 4,
        batch: 8,
        phase: Phase::Decode { kv_len: 4608 },
    });
    let policy = PrefetchPolicy::default();
    let r = common::bench("sim.schedule qwen3 trace", 3, 200, || {
        simulate_trace(&fh, &tr, &policy)
    });
    println!(
        "  -> {:.1} M ops/s through the two-stream engine",
        tr.ops.len() as f64 / r.median_ns * 1e9 / 1e6
    );

    // End-to-end simulate (trace + schedule + occupancy).
    common::bench("sim.simulate gpt3 fh4 decode", 3, 50, || {
        fenghuang::sim::simulate(&fh, &gpt3_175b(), 8, Phase::Decode { kv_len: 4608 }).unwrap()
    });
    common::bench("sim.simulate gpt3 baseline decode", 3, 50, || {
        fenghuang::sim::simulate(&baseline8(), &gpt3_175b(), 8, Phase::Decode { kv_len: 4608 })
            .unwrap()
    });

    // Serving loop: 64 requests through the simulation backend.
    let r = common::bench("coordinator.serve 64 reqs (sim backend)", 1, 10, || {
        let backend = SimBackend::new(fh.clone(), gpt3_175b(), 8);
        let mut sched = Scheduler::new(backend, Batcher::new(8, 64, 131072));
        sched.submit_all(synthetic_workload(64, 1024, 64, Seconds::ms(10.0)));
        sched.run_to_completion().unwrap();
        sched.metrics.completed
    });
    println!("  -> {:.0} requests/s coordinator throughput", 64.0 / r.median_ns * 1e9);

    // TAB pool hot path.
    let pool = Arc::new(TabPool::new(1 << 23, 8, 1024));
    let region = pool.alloc(1 << 21).unwrap();
    let data = vec![1.0f32; 1 << 21];
    let r = common::bench("tab.write_accumulate 8MiB", 3, 50, || {
        pool.write_accumulate(region, 0, &data).unwrap()
    });
    println!("  -> {:.2} GB/s single-thread accumulate", common::gbps(data.len() * 4, r.median_ns));

    // Concurrent accumulate scaling (the TAB's parallel-bank claim).
    for threads in [1usize, 2, 4, 8] {
        let pool = Arc::new(TabPool::new(1 << 24, 16, 1024));
        let region = pool.alloc(1 << 22).unwrap();
        let name = format!("tab.accumulate 4MiB x{threads} threads");
        let r = common::bench(&name, 2, 20, || {
            let hs: Vec<_> = (0..threads)
                .map(|_| {
                    let p = Arc::clone(&pool);
                    std::thread::spawn(move || {
                        let d = vec![1.0f32; 1 << 20];
                        for off in 0..4 {
                            p.write_accumulate(region, off * (1 << 20), &d).unwrap();
                        }
                    })
                })
                .collect();
            hs.into_iter().for_each(|h| h.join().unwrap());
        });
        let total_bytes = threads * 4 * (1 << 20) * 4;
        println!("  -> {:.2} GB/s aggregate", common::gbps(total_bytes, r.median_ns));
    }

    // ---- gate: fixed-size event-core run, the CI regression anchor ------
    // Always 4 replicas × 2000 requests, smoke or not, so every commit's
    // BENCH_perf_hotpath.json carries a comparable number for the
    // scripts/ci.sh perf gate.
    println!("\n== perf-hotpath: event-core gate (4 replicas, 2000 diurnal chat) ==");
    let gate_reqs = diurnal_chat(2000, 40.0);
    let r = common::bench("cluster.event-core gate 4r x 2000", 1, 3, || {
        let mut c = Cluster::fh4(4, &gpt3_175b(), ClusterConfig::default()).unwrap();
        c.run(gate_reqs.clone()).unwrap().fleet.completed
    });
    let gate_ns = r.median_ns;
    println!(
        "  -> {:.0} requests/s through the event core",
        gate_reqs.len() as f64 / gate_ns * 1e9
    );
    json_rows.push(format!(
        "{{\"section\": \"gate\", \"replicas\": 4, \"requests\": 2000, \"event_core_ns\": {gate_ns:.0}}}"
    ));

    // ---- event core vs stepping oracle ----------------------------------
    let (cmp_replicas, cmp_requests, cmp_qps) =
        if smoke { (4usize, 2_000usize, 40.0) } else { (16, 100_000, 200.0) };
    println!(
        "\n== perf-hotpath: event core vs stepping oracle ({cmp_replicas} replicas, {cmp_requests} diurnal chat) =="
    );
    // The workload is regenerated (same seed → identical stream) rather
    // than cloned, so the full-mode 100k-request run never holds two
    // copies in memory at once.
    let reqs = diurnal_chat(cmp_requests, cmp_qps);
    let mut cs = Cluster::fh4(cmp_replicas, &gpt3_175b(), ClusterConfig::default()).unwrap();
    let t0 = Instant::now();
    let rs = cs.run_stepping(reqs).unwrap();
    let stepping_ns = t0.elapsed().as_nanos() as f64;
    let reqs = diurnal_chat(cmp_requests, cmp_qps);
    let mut ce = Cluster::fh4(cmp_replicas, &gpt3_175b(), ClusterConfig::default()).unwrap();
    let t0 = Instant::now();
    let re = ce.run(reqs).unwrap();
    let event_ns = t0.elapsed().as_nanos() as f64;
    // The differential harness (rust/tests/event_core_equiv.rs) pins full
    // bit-identity; the bench sanity-checks the headline counters so a
    // perf number is never reported for a divergent run.
    assert_eq!(rs.fleet.completed, re.fleet.completed, "cores must agree on completions");
    assert_eq!(rs.fleet.tokens_generated, re.fleet.tokens_generated, "cores must agree on tokens");
    assert_eq!(rs.fleet.clock.to_bits(), re.fleet.clock.to_bits(), "cores must agree on makespan");
    let speedup = stepping_ns / event_ns;
    println!(
        "  stepping {:>10.1} ms   event {:>10.1} ms   speedup {speedup:.2}x",
        stepping_ns / 1e6,
        event_ns / 1e6
    );
    if !smoke {
        assert!(
            speedup >= 10.0,
            "event core must beat the stepping oracle by >= 10x at 16x100k (got {speedup:.2}x)"
        );
    }
    json_rows.push(format!(
        "{{\"section\": \"event_vs_stepping\", \"replicas\": {cmp_replicas}, \
         \"requests\": {cmp_requests}, \"stepping_ns\": {stepping_ns:.0}, \
         \"event_ns\": {event_ns:.0}, \"speedup\": {speedup:.3}, \"smoke\": {smoke}}}"
    ));

    // ---- scale: event core only, beyond stepping reach ------------------
    let (scale_replicas, scale_requests) = if smoke { (8usize, 20_000usize) } else { (64, 1_000_000) };
    println!(
        "\n== perf-hotpath: event-core scale ({scale_replicas} replicas, {scale_requests} lean requests) =="
    );
    let reqs = synthetic_workload(scale_requests, 64, 32, Seconds::ms(0.5));
    let mut c = Cluster::fh4(scale_replicas, &gpt3_175b(), ClusterConfig::default()).unwrap();
    let t0 = Instant::now();
    let r = c.run(reqs).unwrap();
    let wall_ns = t0.elapsed().as_nanos() as f64;
    assert_eq!(
        r.fleet.completed + r.fleet.rejected + r.fleet.shed,
        scale_requests as u64,
        "every request must be accounted for at scale"
    );
    let req_per_s = scale_requests as f64 / wall_ns * 1e9;
    let tok_per_s = r.fleet.tokens_generated as f64 / wall_ns * 1e9;
    println!(
        "  wall {:>10.1} ms   {:>9.0} requests/s   {:>11.0} sim-tokens/s   streaming stats: {}",
        wall_ns / 1e6,
        req_per_s,
        tok_per_s,
        r.fleet.ttft.is_streaming(),
    );
    json_rows.push(format!(
        "{{\"section\": \"scale\", \"replicas\": {scale_replicas}, \"requests\": {scale_requests}, \
         \"wall_ns\": {wall_ns:.0}, \"completed\": {}, \"requests_per_s\": {req_per_s:.1}, \
         \"tokens_per_s\": {tok_per_s:.1}, \"smoke\": {smoke}}}",
        r.fleet.completed
    ));

    if common::json_requested() {
        common::write_rows_json("perf_hotpath", &json_rows);
    }
}
