//! Hot-path microbenchmarks (§Perf of EXPERIMENTS.md).
//!
//! L3 targets: trace generation, DES scheduling, whole-simulation
//! latency, serving-loop throughput, TAB accumulate bandwidth. Run before
//! and after each optimization; the iteration log lives in EXPERIMENTS.md.

mod common;

use fenghuang::config::{baseline8, fh4_15xm};
use fenghuang::coordinator::{synthetic_workload, Batcher, Scheduler, SimBackend};
use fenghuang::fabric::tab::TabPool;
use fenghuang::models::arch::{gpt3_175b, qwen3_235b};
use fenghuang::sim::{simulate_trace, PrefetchPolicy};
use fenghuang::trace::{generate, Phase, TraceConfig};
use fenghuang::units::{Bandwidth, Seconds};
use std::sync::Arc;

fn main() {
    let fh = fh4_15xm(Bandwidth::tbps(4.8));

    // Trace generation (per simulation).
    common::bench("trace.generate gpt3 decode", 3, 50, || {
        generate(&TraceConfig {
            model: gpt3_175b(),
            tp: 4,
            batch: 8,
            phase: Phase::Decode { kv_len: 4608 },
        })
    });
    common::bench("trace.generate qwen3 decode (846 ops)", 3, 50, || {
        generate(&TraceConfig {
            model: qwen3_235b(),
            tp: 4,
            batch: 8,
            phase: Phase::Decode { kv_len: 4608 },
        })
    });

    // Pure scheduling over a pre-built trace.
    let tr = generate(&TraceConfig {
        model: qwen3_235b(),
        tp: 4,
        batch: 8,
        phase: Phase::Decode { kv_len: 4608 },
    });
    let policy = PrefetchPolicy::default();
    let r = common::bench("sim.schedule qwen3 trace", 3, 200, || {
        simulate_trace(&fh, &tr, &policy)
    });
    println!(
        "  -> {:.1} M ops/s through the two-stream engine",
        tr.ops.len() as f64 / r.median_ns * 1e9 / 1e6
    );

    // End-to-end simulate (trace + schedule + occupancy).
    common::bench("sim.simulate gpt3 fh4 decode", 3, 50, || {
        fenghuang::sim::simulate(&fh, &gpt3_175b(), 8, Phase::Decode { kv_len: 4608 }).unwrap()
    });
    common::bench("sim.simulate gpt3 baseline decode", 3, 50, || {
        fenghuang::sim::simulate(&baseline8(), &gpt3_175b(), 8, Phase::Decode { kv_len: 4608 })
            .unwrap()
    });

    // Serving loop: 64 requests through the simulation backend.
    let r = common::bench("coordinator.serve 64 reqs (sim backend)", 1, 10, || {
        let backend = SimBackend::new(fh.clone(), gpt3_175b(), 8);
        let mut sched = Scheduler::new(backend, Batcher::new(8, 64, 131072));
        sched.submit_all(synthetic_workload(64, 1024, 64, Seconds::ms(10.0)));
        sched.run_to_completion().unwrap();
        sched.metrics.completed
    });
    println!("  -> {:.0} requests/s coordinator throughput", 64.0 / r.median_ns * 1e9);

    // TAB pool hot path.
    let pool = Arc::new(TabPool::new(1 << 23, 8, 1024));
    let region = pool.alloc(1 << 21).unwrap();
    let data = vec![1.0f32; 1 << 21];
    let r = common::bench("tab.write_accumulate 8MiB", 3, 50, || {
        pool.write_accumulate(region, 0, &data).unwrap()
    });
    println!("  -> {:.2} GB/s single-thread accumulate", common::gbps(data.len() * 4, r.median_ns));

    // Concurrent accumulate scaling (the TAB's parallel-bank claim).
    for threads in [1usize, 2, 4, 8] {
        let pool = Arc::new(TabPool::new(1 << 24, 16, 1024));
        let region = pool.alloc(1 << 22).unwrap();
        let name = format!("tab.accumulate 4MiB x{threads} threads");
        let r = common::bench(&name, 2, 20, || {
            let hs: Vec<_> = (0..threads)
                .map(|_| {
                    let p = Arc::clone(&pool);
                    std::thread::spawn(move || {
                        let d = vec![1.0f32; 1 << 20];
                        for off in 0..4 {
                            p.write_accumulate(region, off * (1 << 20), &d).unwrap();
                        }
                    })
                })
                .collect();
            hs.into_iter().for_each(|h| h.join().unwrap());
        });
        let total_bytes = threads * 4 * (1 << 20) * 4;
        println!("  -> {:.2} GB/s aggregate", common::gbps(total_bytes, r.median_ns));
    }
}
