//! Bench target: open-loop traffic sweep (EXPERIMENTS.md §Traffic-Sweep).
//!
//! 1. Pattern × mix grid on a static 4-replica FH4 fleet per paper
//!    workload (GPT-3 / Grok-1 / QWEN3-235B): SLO attainment, goodput,
//!    tail latency under Poisson / bursty / diurnal arrivals.
//! 2. Elastic vs static: the same diurnal chat+rag stream served by a
//!    static 8-replica fleet and by the autoscaler breathing between 1
//!    and 8 replicas — GPU-hours (replica-seconds) vs SLO attainment,
//!    the closed-loop form of the paper's 50 %-fewer-GPUs claim (§4.4).
//!
//! `cargo bench --bench traffic_sweep -- --json` writes
//! `BENCH_traffic_sweep.json` at the repo root (scripts/bench_json.sh);
//! `-- --smoke` (scripts/ci.sh) shrinks the grid to a CI-sized run.

mod common;

use fenghuang::coordinator::{AutoscaleConfig, Cluster, ClusterConfig, ClusterReport, SloTarget};
use fenghuang::models::arch::{gpt3_175b, grok1, qwen3_235b, ModelArch};
use fenghuang::traffic::{self, ArrivalConfig, ArrivalPattern, TrafficConfig, WorkloadMix};
use fenghuang::units::Seconds;

const SEED: u64 = 7;

fn traffic(
    model: &ModelArch,
    pattern: ArrivalPattern,
    mix: &str,
    qps: f64,
    requests: usize,
    slo: SloTarget,
) -> TrafficConfig {
    TrafficConfig {
        arrivals: ArrivalConfig { pattern, qps, ..Default::default() },
        mix: WorkloadMix::parse(mix).expect("mix"),
        requests,
        seed: SEED,
        max_prompt: model.max_seq as usize,
        slo: Some(slo),
    }
}

fn run(model: &ModelArch, replicas: usize, cfg: ClusterConfig, tc: &TrafficConfig) -> ClusterReport {
    let mut cluster = Cluster::fh4(replicas, model, cfg).expect("cluster");
    cluster.run(traffic::generate(tc).expect("workload")).expect("run")
}

fn main() {
    let smoke = common::smoke();
    let mut json_rows: Vec<String> = Vec::new();

    // ---- 1. pattern × mix grid, static 4-replica fleet ------------------
    let models: Vec<ModelArch> = if smoke {
        vec![gpt3_175b()]
    } else {
        vec![gpt3_175b(), grok1(), qwen3_235b()]
    };
    let mixes: &[&str] = if smoke { &["chat"] } else { &["chat", "chat+rag", "agentic+batch"] };
    let grid_requests = if smoke { 12 } else { 256 };
    let base_slo = SloTarget { ttft: Seconds::ms(2000.0), tpot: Seconds::ms(80.0) };

    println!("== traffic-sweep: pattern × mix grid (4 replicas, {grid_requests} requests, qps 8, seed {SEED}) ==");
    println!("model     pattern  mix            attain%  goodput(tok/s)  p95 TTFT(ms)  p95 TPOT(ms)  makespan(s)");
    for model in &models {
        for pattern in ArrivalPattern::synthetic() {
            for mix in mixes {
                let tc = traffic(model, pattern, mix, 8.0, grid_requests, base_slo);
                let r = run(model, 4, ClusterConfig::default(), &tc);
                println!(
                    "{:<9} {:<8} {:<14} {:>6.1}  {:>14.1}  {:>12.1}  {:>12.2}  {:>11.2}",
                    model.name,
                    pattern.name(),
                    mix,
                    100.0 * r.fleet.slo_attainment(),
                    r.fleet.goodput_tokens_per_s(),
                    r.fleet.ttft.percentile_ms(95.0),
                    r.fleet.tpot.percentile_ms(95.0),
                    r.makespan().value(),
                );
                json_rows.push(format!(
                    "{{\"section\": \"grid\", \"model\": {}, \"pattern\": {}, \"mix\": {}, \
                     \"attainment\": {:.4}, \"goodput_tok_s\": {:.3}, \"p95_ttft_ms\": {:.3}, \
                     \"p95_tpot_ms\": {:.4}, \"makespan_s\": {:.6}, \"completed\": {}, \
                     \"shed\": {}}}",
                    common::json_str(&model.name),
                    common::json_str(pattern.name()),
                    common::json_str(mix),
                    r.fleet.slo_attainment(),
                    r.fleet.goodput_tokens_per_s(),
                    r.fleet.ttft.percentile_ms(95.0),
                    r.fleet.tpot.percentile_ms(95.0),
                    r.makespan().value(),
                    r.fleet.completed,
                    r.fleet.shed,
                ));
            }
        }
    }

    // ---- 2. elastic vs static under a diurnal curve ---------------------
    // Fixed SLO, diurnal chat+rag at 12 qps peak: the static fleet is
    // provisioned for the peak all day; the autoscaler follows the curve.
    // The claim (EXPERIMENTS.md §Traffic-Sweep): the elastic fleet meets
    // the same SLO with ≥ 30 % fewer replica-seconds.
    let elastic_models: Vec<ModelArch> =
        if smoke { vec![gpt3_175b()] } else { vec![gpt3_175b(), qwen3_235b()] };
    let elastic_requests = if smoke { 32 } else { 1024 };
    let elastic_slo = SloTarget { ttft: Seconds::ms(4000.0), tpot: Seconds::ms(150.0) };

    println!("\n== traffic-sweep: elastic vs static (diurnal chat+rag, 8-replica fleet, qps 12 peak) ==");
    println!("model     config    attain%  goodput(tok/s)  replica-s  GPU-s   saving");
    for model in &elastic_models {
        let tc = traffic(
            model,
            ArrivalPattern::Diurnal,
            "chat+rag",
            12.0,
            elastic_requests,
            elastic_slo,
        );
        let stat = run(model, 8, ClusterConfig::default(), &tc);
        // Target ≈ 75 % of a replica's in-flight capacity (max_batch 8 ×
        // ~1.6k work tokens for this mix): provisions headroom for the
        // SLO while letting the trough actually scale down.
        let auto_cfg = ClusterConfig {
            autoscale: Some(AutoscaleConfig { target_tokens: 8192, ..Default::default() }),
            ..Default::default()
        };
        let auto = run(model, 8, auto_cfg, &tc);
        let saving = 1.0 - auto.replica_seconds / stat.replica_seconds.max(1e-12);
        for (label, r) in [("static-8", &stat), ("elastic", &auto)] {
            println!(
                "{:<9} {:<9} {:>6.1}  {:>14.1}  {:>9.1}  {:>6.1}  {}",
                model.name,
                label,
                100.0 * r.fleet.slo_attainment(),
                r.fleet.goodput_tokens_per_s(),
                r.replica_seconds,
                r.gpu_seconds,
                if r.elastic { format!("{:.1}%", 100.0 * saving) } else { "—".to_string() },
            );
        }
        let meets = auto.fleet.slo_attainment() >= 0.9 && stat.fleet.slo_attainment() >= 0.9;
        println!(
            "  → elastic saving {:.1}% of replica-seconds at equal SLO ({} scale events, meets-SLO: {})",
            100.0 * saving,
            auto.scale_events.len(),
            meets,
        );
        json_rows.push(format!(
            "{{\"section\": \"elastic\", \"model\": {}, \"slo_ttft_ms\": {:.1}, \
             \"slo_tpot_ms\": {:.1}, \"static_attainment\": {:.4}, \"elastic_attainment\": {:.4}, \
             \"static_replica_s\": {:.4}, \"elastic_replica_s\": {:.4}, \
             \"static_gpu_s\": {:.4}, \"elastic_gpu_s\": {:.4}, \"saving_frac\": {:.4}, \
             \"scale_events\": {}, \"meets_slo\": {}}}",
            common::json_str(&model.name),
            elastic_slo.ttft.as_ms(),
            elastic_slo.tpot.as_ms(),
            stat.fleet.slo_attainment(),
            auto.fleet.slo_attainment(),
            stat.replica_seconds,
            auto.replica_seconds,
            stat.gpu_seconds,
            auto.gpu_seconds,
            saving,
            auto.scale_events.len(),
            meets,
        ));
    }

    if common::json_requested() {
        common::write_rows_json("traffic_sweep", &json_rows);
    }
}
