//! Bench target: multi-tenant isolation sweep (EXPERIMENTS.md
//! §Tenant-Sweep).
//!
//! The question this bench exists to ask: when tenant B dumps a batch
//! burst on the shared fleet, how much of tenant A's interactive tail
//! does each admission policy give away? Three sections:
//!
//! * **passthrough** — a single-tenant `TenantsConfig` is bit-identical
//!   to the tenants-off fleet (the tenancy machinery is free when
//!   unused);
//! * **burst sweep** — tenant A's steady chat lane against a B batch
//!   burst swept over burst sizes, under DRR weighted fair queueing and
//!   under global-FIFO admission. The wall this bench pins: WFQ's
//!   tenant-A p99-TTFT degradation (vs A running solo) is *strictly*
//!   smaller than FIFO's at every burst size — FIFO parks A's arrivals
//!   behind B's backlog even though A's home replica is idle;
//! * **cold start** — a third tenant with no home replica must page its
//!   model in through the pool: swaps and cold-start latency are
//!   reported as first-class per-tenant metrics.
//!
//! `cargo bench --bench tenant_sweep -- --json` writes
//! `BENCH_tenant_sweep.json` (scripts/bench_json.sh `tenants`);
//! `-- --smoke` (scripts/ci.sh) shrinks the sweep.

mod common;

use fenghuang::coordinator::tenancy::{TenantArbitration, TenantsConfig};
use fenghuang::coordinator::{Cluster, ClusterConfig, ClusterReport, Request};
use fenghuang::models::arch::gpt3_175b;
use fenghuang::traffic::{generate_tenant_workload, ArrivalConfig, ArrivalPattern, TrafficConfig};
use fenghuang::units::Seconds;

const REPLICAS: usize = 2;
const ADMIT_TOKENS: u64 = 1500;

/// Tenant A: steady interactive traffic, one request every 80 ms.
fn chat_lane(requests: usize) -> Vec<Request> {
    (0..requests)
        .map(|i| Request {
            id: i as u64,
            prompt: vec![(i % 509) as i32 + 1; 200],
            max_new_tokens: 40,
            arrival: Seconds::new(0.08 * i as f64),
            tenant: 0,
            ..Default::default()
        })
        .collect()
}

/// Tenant B: `burst` heavyweight batch requests dumped at t = 50 ms
/// (prompt + generation inside gpt2's 1024-token context).
fn batch_burst(burst: usize) -> Vec<Request> {
    (0..burst)
        .map(|i| Request {
            id: (1 << 40) | i as u64,
            prompt: vec![((i + 7) % 509) as i32 + 1; 600],
            max_new_tokens: 200,
            arrival: Seconds::new(0.05),
            tenant: 1,
            ..Default::default()
        })
        .collect()
}

fn merged(requests: usize, burst: usize) -> Vec<Request> {
    let mut reqs = chat_lane(requests);
    reqs.extend(batch_burst(burst));
    reqs.sort_by(|x, y| x.arrival.partial_cmp(&y.arrival).expect("finite arrivals"));
    reqs
}

fn two_tenants(mode: TenantArbitration) -> TenantsConfig {
    let mut tc = TenantsConfig::parse("alpha/gpt2,beta/gpt2").expect("spec");
    tc.arbitration = mode;
    tc.admit_tokens = Some(ADMIT_TOKENS);
    tc
}

fn run(cfg: ClusterConfig, reqs: Vec<Request>) -> ClusterReport {
    let mut cluster = Cluster::fh4(REPLICAS, &gpt3_175b(), cfg).expect("cluster");
    cluster.run(reqs).expect("run")
}

fn tenant_p99(r: &ClusterReport, tenant: usize) -> f64 {
    r.tenants.as_ref().expect("tenant reports")[tenant].ttft.percentile_ms(99.0)
}

fn main() {
    let smoke = common::smoke();
    let mut json_rows: Vec<String> = Vec::new();
    let requests = if smoke { 16 } else { 24 };

    // ── Passthrough: a single-tenant config must not move a bit ──
    let plain = run(ClusterConfig::default(), chat_lane(requests));
    let single = run(
        ClusterConfig {
            tenants: Some(TenantsConfig::single(gpt3_175b())),
            ..Default::default()
        },
        chat_lane(requests),
    );
    for (label, a, b) in [
        ("makespan", plain.makespan().value(), single.makespan().value()),
        ("ttft_p99", plain.fleet.ttft.percentile_ms(99.0), single.fleet.ttft.percentile_ms(99.0)),
        ("busy", plain.fleet.busy.value(), single.fleet.busy.value()),
        ("swap_stall", plain.fleet.swap_stall.value(), single.fleet.swap_stall.value()),
    ] {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "single-tenant config perturbed `{label}`: {a} vs {b}"
        );
    }
    println!("passthrough: single-tenant config bit-identical to tenants-off ✓\n");

    // ── Burst sweep: B steals bandwidth from A, per arbitration mode ──
    let solo = run(
        ClusterConfig { tenants: Some(two_tenants(TenantArbitration::Wfq)), ..Default::default() },
        chat_lane(requests),
    );
    let solo_p99 = tenant_p99(&solo, 0);
    let bursts: &[usize] = if smoke { &[8, 16] } else { &[4, 8, 16, 24] };
    println!(
        "== tenant burst sweep (gpt2×2 tenants, {REPLICAS} replicas, {requests} chat req, \
         gate {ADMIT_TOKENS} tok, solo A p99 {solo_p99:.2} ms) =="
    );
    println!("burst  mode  A-p99(ms)  A-degr(ms)  B-p99(ms)  completed");
    let mut prev_fifo_deg = -1.0f64;
    for &burst in bursts {
        let mut degr = [0.0f64; 2];
        for (mi, mode) in [TenantArbitration::Wfq, TenantArbitration::Fifo].into_iter().enumerate()
        {
            let r = run(
                ClusterConfig { tenants: Some(two_tenants(mode)), ..Default::default() },
                merged(requests, burst),
            );
            assert_eq!(
                r.fleet.completed as usize,
                requests + burst,
                "conservation: every request completes"
            );
            let a_p99 = tenant_p99(&r, 0);
            let b_p99 = tenant_p99(&r, 1);
            let deg = a_p99 - solo_p99;
            degr[mi] = deg;
            println!(
                "{burst:>5}  {:<4}  {a_p99:>9.2}  {deg:>10.2}  {b_p99:>9.2}  {:>9}",
                mode.name(),
                r.fleet.completed
            );
            json_rows.push(format!(
                "{{\"section\": \"burst\", \"burst\": {burst}, \"mode\": {}, \
                 \"a_p99_ms\": {a_p99:.4}, \"a_solo_p99_ms\": {solo_p99:.4}, \
                 \"a_degradation_ms\": {deg:.4}, \"b_p99_ms\": {b_p99:.4}, \
                 \"completed\": {}}}",
                common::json_str(mode.name()),
                r.fleet.completed
            ));
        }
        // The wall: fair queueing must give away strictly less of A's
        // tail than the no-isolation baseline, at every burst size.
        assert!(
            degr[0] < degr[1],
            "WFQ must degrade tenant A strictly less than FIFO at burst {burst}: \
             wfq +{:.3} ms vs fifo +{:.3} ms",
            degr[0],
            degr[1]
        );
        // FIFO's damage grows with the backlog parked ahead of A.
        assert!(
            degr[1] >= prev_fifo_deg - 1e-9,
            "FIFO degradation fell as the burst grew: +{:.3} ms after +{:.3} ms",
            degr[1],
            prev_fifo_deg
        );
        prev_fifo_deg = degr[1];
    }

    // ── Cold start: a homeless tenant pages its model in via the pool ──
    let mut spec = TenantsConfig::parse(
        "alpha/gpt2/weight=3/mix=chat,beta/gpt2-xl/mix=batch,gamma/gpt2/mix=rag",
    )
    .expect("spec");
    spec.admit_tokens = Some(2048);
    let tc = TrafficConfig {
        arrivals: ArrivalConfig {
            pattern: ArrivalPattern::Bursty,
            qps: 16.0,
            ..Default::default()
        },
        requests: if smoke { 18 } else { 27 },
        seed: 23,
        max_prompt: 1024,
        slo: None,
        ..Default::default()
    };
    let reqs = generate_tenant_workload(&spec, &tc).expect("workload");
    let r = run(ClusterConfig { tenants: Some(spec), ..Default::default() }, reqs);
    let ts = r.tenants.as_ref().expect("tenant reports");
    let swaps: u64 = ts.iter().map(|t| t.swaps).sum();
    assert!(swaps > 0, "three tenants on two replicas must cold-start at least once");
    assert!(
        r.fleet.swap_stall.value() > 0.0,
        "cold starts must charge swap stalls into the fleet ledger"
    );
    println!("\n== cold start (3 tenants, {REPLICAS} replicas) ==");
    println!("tenant  swaps  cold-start-total(ms)  p99-cold(ms)  pool-held(GB)");
    for t in ts {
        println!(
            "{:<6}  {:>5}  {:>20.2}  {:>12.2}  {:>13.3}",
            t.name,
            t.swaps,
            t.cold_start_total.as_ms(),
            t.cold_start.percentile_ms(99.0),
            t.pool_bytes_held.as_gb()
        );
        json_rows.push(format!(
            "{{\"section\": \"cold_start\", \"tenant\": {}, \"swaps\": {}, \
             \"cold_start_total_ms\": {:.4}, \"cold_start_p99_ms\": {:.4}, \
             \"pool_bytes_held_gb\": {:.6}, \"completed\": {}}}",
            common::json_str(&t.name),
            t.swaps,
            t.cold_start_total.as_ms(),
            t.cold_start.percentile_ms(99.0),
            t.pool_bytes_held.as_gb(),
            t.completed
        ));
    }

    if common::json_requested() {
        common::write_rows_json("tenant_sweep", &json_rows);
    }
}
